"""Batched serving engine: slot-based continuous batching over the model's
prefill/decode steps.

Requests queue up; the engine owns ``max_batch`` decode slots with a
shared KV/SSM cache of ``max_len``.  Each slot tracks its own position —
``decode_step`` takes a PER-SLOT position vector, so sequences of
different lengths decode together and a finished slot is refilled from
the queue without draining the batch (continuous batching).  Prefill runs
one request at a time into its slot (chunked prefill for long prompts is
the model's blocked-attention path).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.engine.telemetry import resolve_telemetry
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None      # set on structured rejection


class ServingEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_len: int = 256, telemetry=None):
        assert not model.cfg.is_encoder, "encoder archs do not serve decode"
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        # Same telemetry layer as the SpGEMM engine: serve spans/latency
        # histograms land in the registry a /metrics endpoint would
        # render via ``repro.engine.telemetry.prometheus_text``-style
        # exposition.  No extra fences: prefill/decode already host-sync
        # on the argmax token reads the spans wrap.
        self.telemetry = resolve_telemetry(telemetry)
        reg = self.telemetry.registry
        self._ctr_requests = reg.counter("opsparse_serve_requests_total")
        self._ctr_rejected = reg.counter("opsparse_serve_rejected_total")
        self._ctr_tokens = reg.counter("opsparse_serve_tokens_total")
        self._hist_prefill = reg.histogram("opsparse_serve_prefill_seconds")
        self._hist_decode = reg.histogram(
            "opsparse_serve_decode_step_seconds")
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)        # per-slot position
        self.caches = model.init_caches(max_batch, max_len)
        self.last_token = np.zeros((max_batch, 1), np.int32)
        self._decode = jax.jit(model.decode_step)
        self._prefill_one = jax.jit(
            lambda p, b: model.prefill(p, b, kv_cache_len=max_len))

    # -- public API ---------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def run(self, max_steps: int = 1000) -> Dict[int, List[int]]:
        """Drive until queue + slots drain (or step budget).

        Rejected requests (e.g. a prompt that cannot fit ``max_len``)
        appear in the results with their (empty) output and a set
        ``req.error`` — a malformed request is the CLIENT's failure,
        and it must not take the engine down for everyone else's.
        """
        results: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            self._fill_slots(results)
            if not any(s is not None for s in self.slots):
                break
            self._decode_once(results)
        return results

    # -- internals ----------------------------------------------------------
    def _fill_slots(self, results: Dict[int, List[int]]):
        for i in range(self.max_batch):
            # A rejected request frees its slot immediately — keep
            # pulling from the queue until a request actually lands (or
            # the queue drains) so one bad request can't idle the slot
            # for a whole decode step.
            while self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                if self._prefill_into_slot(i, req):
                    break
                results[req.uid] = req.output

    def _prefill_into_slot(self, i: int, req: Request) -> bool:
        """Prefill ``req`` into slot ``i``; False = structured rejection
        (the request is marked done-with-error, the engine keeps going)."""
        plen = len(req.prompt)
        if plen >= self.max_len:
            req.error = (f"prompt length {plen} >= max_len "
                         f"{self.max_len}; request rejected")
            req.done = True
            self._ctr_rejected.inc()
            self.telemetry.event("serve_reject", uid=req.uid,
                                 prompt_len=plen, max_len=self.max_len)
            return False
        self._ctr_requests.inc()
        with self.telemetry.span("serve.prefill", uid=req.uid,
                                 slot=i, prompt_len=plen) as span:
            batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
            logits, caches = self._prefill_one(self.params, batch)
            tok = int(jnp.argmax(logits[0, -1]))   # host sync ends the span
            self._write_slot_cache(i, caches)
        if self.telemetry.enabled:
            self._hist_prefill.observe(span.dur)
        self._ctr_tokens.inc()
        self.slots[i] = req
        self.pos[i] = plen
        self.last_token[i, 0] = tok
        req.output.append(tok)
        return True

    def _write_slot_cache(self, i: int, caches):
        """Copy a 1-sequence prefill cache into batch slot i."""
        def copy(dst, src):
            # batch dim differs between attn (B at -4) and ssm leaves; the
            # 1-sized dim of src aligned with dst's max_batch dim is B.
            for ax, (ds, ss) in enumerate(zip(dst.shape, src.shape)):
                if ds == self.max_batch and ss == 1:
                    return jax.lax.dynamic_update_slice_in_dim(
                        dst, src.astype(dst.dtype), i, axis=ax)
            raise ValueError((dst.shape, src.shape))

        self.caches = jax.tree_util.tree_map(copy, self.caches, caches)

    def _decode_once(self, results: Dict[int, List[int]]):
        active = sum(s is not None for s in self.slots)
        with self.telemetry.span("serve.decode_step",
                                 active_slots=active) as span:
            pos = jnp.asarray(self.pos, jnp.int32)
            tok = jnp.asarray(self.last_token, jnp.int32)
            logits, self.caches = self._decode(
                self.params, tok, self.caches, pos)
            # np.asarray is the step's existing host sync — the span
            # boundary rides it rather than adding a fence.
            next_np = np.asarray(jnp.argmax(logits[:, -1], axis=-1))[:, None]
        if self.telemetry.enabled:
            self._hist_decode.observe(span.dur)
        self._ctr_tokens.inc(active)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            t = int(next_np[i, 0])
            req.output.append(t)
            self.pos[i] += 1
            self.last_token[i, 0] = t
            hit_eos = req.eos_id is not None and t == req.eos_id
            if len(req.output) >= req.max_new_tokens or hit_eos or \
                    self.pos[i] >= self.max_len - 1:
                req.done = True
                results[req.uid] = req.output
                self.slots[i] = None
        return results
