"""Fault-tolerant SpGEMM serving front-end.

The engine (``repro.engine.executor``) already recovers from everything
it can observe *inside* one request — capacity overflows redo bitwise
through the steps oracle, governor pressure walks a four-rung
degradation ladder down to :class:`~repro.core.workspace.
ArenaPressureError` backpressure.  What it cannot do is decide what a
*request* is worth: whether a denied lease should be retried and when,
whether a deadline still has budget for a cold plan, which tenant's
traffic a shared cap should shed first.  :class:`SpgemmService` owns
those request-level decisions:

Tenancy
    Each tenant gets its own :class:`~repro.engine.executor.SpgemmEngine`
    — a private plan-cache namespace and metrics registry — while ALL
    tenants share one :class:`~repro.core.workspace.Arena` bounded by
    one :class:`~repro.engine.autotune.MemoryGovernor` cap (the
    multi-tenant discipline PR 7 established).  One tenant's plan churn
    cannot evict another's plans; one tenant's workspace burst is
    bounded by the same cap as everyone else's.

Deadlines
    ``call(..., deadline_s=...)`` is admission-controlled up front:
    a hot plan's predicted latency is the steady-state histogram's
    conservative quantile; a cold plan's is a per-tenant seconds-per-
    flop EWMA (calibrated from observed cold calls) times the request's
    flop count, falling back to the cold-path histogram.  A request
    predicted to blow its budget — or one that expires between retries —
    returns a structured ``status="timeout"`` result.  No exception
    escapes :meth:`SpgemmService.call`.

Retry + degradation ladder
    Failures are classified: :class:`ArenaPressureError` and *transient*
    :class:`~repro.core.faults.InjectedFault` retry with exponential
    backoff and seeded jitter, walking a service-level ladder that
    extends the governor's —

      rung 0  reclaim the arena's idle leases and retry unchanged
      rung 1  shed sharding (``shards=1``): fan-out multiplies workspace
      rung 2  spill fused numeric to the two-pass schedule (hash only)
      rung 3  reject with ``retry_after_s`` backpressure for the client

    Non-transient failures never retry — they return a structured
    ``status="error"`` result immediately (a poisoned request must not
    burn its tenant's budget three more times).

Fault injection
    A seeded :class:`~repro.core.faults.FaultPlan` threads through the
    service into every tenant engine, so CI can provoke each rung
    deterministically (``benchmarks/bench_engine.py --serve``) and
    assert the recovered results stay bitwise identical to a fault-free
    run.

Observability
    :meth:`SpgemmService.prometheus_text` merges every tenant engine's
    sample blocks under ``tenant="<name>"`` labels plus service-level
    counters (retries, timeouts, sheds, spills, rejections, faults
    survived) into one exposition document, served by
    :class:`MetricsHTTPServer` — a stdlib ``http.server`` endpoint with
    ``GET /metrics`` and ``GET /healthz``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.csr import CSR
from repro.core.faults import FaultPlan, InjectedFault, resolve_faults
from repro.core.spgemm import SpgemmConfig, SpgemmResult
from repro.core.workspace import Arena, ArenaPressureError
from repro.engine.autotune import MemoryGovernor
from repro.engine.executor import SpgemmEngine
from repro.engine.plan import MatrixSig
from repro.engine.telemetry import (MetricsRegistry, engine_sample_blocks,
                                    histogram_quantile, merge_sample_blocks)

# Degradation rungs above the governor's, walked in order by the retry
# loop; a rung that does not apply to the request's config is skipped.
SERVICE_RUNGS: Tuple[str, ...] = ("reclaim", "shed_shards",
                                  "spill_two_pass")


@dataclasses.dataclass
class ServiceResult:
    """What every :meth:`SpgemmService.call` returns — success or not.

    ``status``   "ok" | "timeout" | "rejected" | "error"
    ``value``    the :class:`SpgemmResult` when ``status == "ok"``
    ``error``    human-readable failure description otherwise
    ``retries``  transient-failure retries this request consumed
    ``degraded`` deepest service rung the request walked (None = none)
    ``retry_after_s``  backpressure hint on "rejected" results: the
                 client should wait at least this long before resubmit
    ``faults_survived``  injected faults absorbed on the way to "ok"
    """

    status: str
    tenant: str
    value: Optional[SpgemmResult] = None
    error: Optional[str] = None
    retries: int = 0
    degraded: Optional[str] = None
    retry_after_s: Optional[float] = None
    elapsed_s: float = 0.0
    faults_survived: int = 0

    @property
    def ok(self) -> bool:
        return self.status == "ok"


class _Tenant:
    """One tenant namespace: a private engine (plan cache + registry)
    plus the service-level counters rendered under its label."""

    def __init__(self, name: str, engine: SpgemmEngine):
        self.name = name
        self.engine = engine
        # Engine calls for one tenant are serialized (the engine's
        # dispatch/finalize bookkeeping is single-stream); cross-tenant
        # concurrency is safe because the shared arena and fault plan
        # carry their own locks.
        self.lock = threading.Lock()
        # Cold-call cost model: EWMA of observed seconds per flop,
        # calibrated after every cold (unspecialized-plan) call.  None
        # until the first cold call completes.
        self.cold_s_per_flop: Optional[float] = None  # guarded-by: lock
        reg = engine.telemetry.registry
        self.c_requests = reg.counter("opsparse_service_requests_total")
        self.c_retries = reg.counter("opsparse_service_retries_total")
        self.c_timeouts = reg.counter("opsparse_service_timeouts_total")
        self.c_sheds = reg.counter("opsparse_service_sheds_total")
        self.c_spills = reg.counter("opsparse_service_spills_total")
        self.c_rejected = reg.counter("opsparse_service_rejected_total")
        self.c_errors = reg.counter("opsparse_service_errors_total")
        self.c_faults_survived = reg.counter(
            "opsparse_service_faults_survived_total")


class SpgemmService:
    """Multi-tenant, deadline-aware, fault-tolerant SpGEMM front-end.

    ::

        svc = SpgemmService(governor=MemoryGovernor(cap_bytes=64 << 20))
        r = svc.call(A, B, tenant="acme", deadline_s=0.5)
        if r.ok:
            use(r.value)
        elif r.status == "rejected":
            resubmit_after(r.retry_after_s)

    No exception escapes :meth:`call` — every outcome is a structured
    :class:`ServiceResult`.  See the module docstring for the full
    contract.
    """

    def __init__(self, config: Optional[SpgemmConfig] = None, *,
                 governor: Optional[MemoryGovernor] = None,
                 arena: Optional[Arena] = None,
                 faults: Optional[FaultPlan] = None,
                 max_tenants: int = 8,
                 cache_capacity: int = 64,
                 max_retries: int = 3,
                 backoff_base_s: float = 0.005,
                 backoff_cap_s: float = 0.25,
                 backoff_jitter: float = 0.5,
                 deadline_quantile: float = 0.99,
                 telemetry: bool = True,
                 seed: int = 0):
        self.config = config or SpgemmConfig()
        self.governor = governor or MemoryGovernor()
        # A PRIVATE arena by default (not the process-global default
        # arena): the service's cap and fault schedule must not leak
        # into unrelated engines in the same process.
        self.arena = arena if arena is not None else Arena()
        self.faults = resolve_faults(faults)
        self.max_tenants = int(max_tenants)
        self.cache_capacity = int(cache_capacity)
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.backoff_jitter = float(backoff_jitter)
        self.deadline_quantile = float(deadline_quantile)
        self.telemetry_enabled = bool(telemetry)
        self._rng = random.Random(seed)  # guarded-by: _lock
        self._lock = threading.Lock()
        self._tenants: "Dict[str, _Tenant]" = {}  # guarded-by: _lock
        # Service-wide registry: cross-tenant counters that have no
        # tenant label (admission rejections name tenants that were
        # never admitted, so they cannot live in a tenant registry).
        self.registry = MetricsRegistry()
        self._g_tenants = self.registry.gauge("opsparse_service_tenants")
        self._c_admission_rejected = self.registry.counter(
            "opsparse_service_admission_rejected_total")
        self._http: Optional[MetricsHTTPServer] = None  # guarded-by: _lock

    # -- tenancy ------------------------------------------------------------
    def _get_tenant(self, name: str) -> Optional[_Tenant]:
        """Admit-or-return the tenant namespace; ``None`` means the
        tenant roster is full (the caller renders a rejection)."""
        with self._lock:
            ten = self._tenants.get(name)
            if ten is not None:
                return ten
            if len(self._tenants) >= self.max_tenants:
                self._c_admission_rejected.inc()
                return None
            engine = SpgemmEngine(
                self.config, cache_capacity=self.cache_capacity,
                telemetry=self.telemetry_enabled, arena=self.arena,
                governor=self.governor, faults=self.faults)
            ten = self._tenants[name] = _Tenant(name, engine)
            self._g_tenants.set(len(self._tenants))
            return ten

    def tenants(self) -> List[str]:
        with self._lock:
            return sorted(self._tenants)

    def engine(self, tenant: str = "default") -> SpgemmEngine:
        """The tenant's engine (admitting the tenant if needed) — for
        tests and prewarm flows; raises if the roster is full."""
        ten = self._get_tenant(tenant)
        if ten is None:
            raise RuntimeError(
                f"tenant roster full ({self.max_tenants}); "
                f"cannot admit {tenant!r}")
        return ten.engine

    # -- failure classification + ladder ------------------------------------
    @staticmethod
    def classify_failure(exc: BaseException) -> str:
        """``"pressure"`` (retry with backoff + ladder) or ``"fatal"``
        (structured error, NO retry).  Injected faults carry their own
        classification; anything unrecognized is fatal — retrying an
        unknown failure mode re-runs unknown side effects."""
        if isinstance(exc, ArenaPressureError):
            return "pressure"
        if isinstance(exc, InjectedFault):
            return "pressure" if exc.transient else "fatal"
        return "fatal"

    def _next_rung(self, rung: Optional[str],
                   config: SpgemmConfig) -> Optional[str]:
        """The next *applicable* service rung after ``rung`` (None =
        start of ladder); returns None when the ladder is exhausted."""
        start = 0 if rung is None else SERVICE_RUNGS.index(rung) + 1
        for cand in SERVICE_RUNGS[start:]:
            if cand == "shed_shards" and config.shards == 1:
                continue
            if cand == "spill_two_pass" and not (
                    config.method == "hash" and config.fuse_numeric):
                continue
            return cand
        return None

    def _apply_rung(self, ten: _Tenant, rung: str,
                    config: SpgemmConfig) -> SpgemmConfig:
        """Execute one rung's action; returns the (possibly degraded)
        config the retry should run under."""
        if rung == "reclaim":
            self.arena.reclaim()
            return config
        if rung == "shed_shards":
            ten.c_sheds.inc()
            ten.engine.telemetry.event("service_shed_shards",
                                       tenant=ten.name)
            return dataclasses.replace(config, shards=1)
        ten.c_spills.inc()
        ten.engine.telemetry.event("service_spill_two_pass",
                                   tenant=ten.name)
        return dataclasses.replace(config, fuse_numeric=False)

    # -- deadline admission --------------------------------------------------
    def _flops(self, A: CSR, B: CSR) -> int:
        from repro.core.analysis import row_flops  # host sync: lazy
        return max(1, int(row_flops(A, B).sum()))

    def _plan_entry(self, ten: _Tenant, A: CSR, B: CSR,
                    config: SpgemmConfig):
        key = (MatrixSig.of(A), MatrixSig.of(B), config)
        return ten.engine.cache.peek(key)

    def _predict_latency_s(self, ten: _Tenant, A: CSR, B: CSR,
                           config: SpgemmConfig) -> Optional[float]:
        """Conservative latency prediction for deadline admission;
        ``None`` = no basis to predict, admit blind."""
        reg = ten.engine.telemetry.registry
        entry = self._plan_entry(ten, A, B, config)
        if entry is not None and entry.plan.is_specialized:
            return histogram_quantile(
                reg.get("opsparse_request_latency_seconds"),
                self.deadline_quantile)
        if ten.cold_s_per_flop is not None:
            return ten.cold_s_per_flop * self._flops(A, B)
        return histogram_quantile(reg.get("opsparse_cold_steps_seconds"),
                                  self.deadline_quantile)

    def _calibrate_cold(self, ten: _Tenant, A: CSR, B: CSR,
                        dt: float) -> None:
        per_flop = dt / self._flops(A, B)
        prev = ten.cold_s_per_flop
        ten.cold_s_per_flop = (per_flop if prev is None
                               else 0.7 * prev + 0.3 * per_flop)

    def _backoff_s(self, attempt: int) -> float:
        base = min(self.backoff_cap_s,
                   self.backoff_base_s * (2.0 ** attempt))
        with self._lock:
            jitter = self._rng.random()
        return base * (1.0 + self.backoff_jitter * jitter)

    # -- the request loop ----------------------------------------------------
    def call(self, A: CSR, B: CSR, *, tenant: str = "default",
             config: Optional[SpgemmConfig] = None,
             deadline_s: Optional[float] = None) -> ServiceResult:
        """Execute one product under the service contract.

        Never raises: timeouts, rejections, and errors all come back as
        structured :class:`ServiceResult` values (see class docstring).
        """
        t0 = time.perf_counter()
        ten = self._get_tenant(tenant)
        if ten is None:
            return ServiceResult(
                status="rejected", tenant=tenant,
                error=f"tenant roster full ({self.max_tenants} tenants)",
                retry_after_s=self.governor.retry_after_s)
        deadline = None if deadline_s is None else t0 + float(deadline_s)

        with ten.lock:
            ten.c_requests.inc()
            cfg = ten.engine._effective_config(config)
            faults_before = ten.engine.stats.faults_injected

            # Up-front admission: don't start work a budget can't absorb.
            if deadline is not None:
                pred = self._predict_latency_s(ten, A, B, cfg)
                if pred is not None \
                        and time.perf_counter() + pred > deadline:
                    ten.c_timeouts.inc()
                    return ServiceResult(
                        status="timeout", tenant=tenant,
                        error=("deadline %.3fs < predicted latency %.3fs"
                               % (deadline_s, pred)),
                        elapsed_s=time.perf_counter() - t0)

            retries = 0
            rung: Optional[str] = None
            while True:
                entry = self._plan_entry(ten, A, B, cfg)
                was_hot = entry is not None and entry.plan.is_specialized
                try:
                    t_call = time.perf_counter()
                    value = ten.engine.execute(A, B, cfg)
                except Exception as exc:  # noqa: BLE001 — classified below
                    kind = self.classify_failure(exc)
                    if kind == "fatal":
                        ten.c_errors.inc()
                        return ServiceResult(
                            status="error", tenant=tenant,
                            error=f"{type(exc).__name__}: {exc}",
                            retries=retries, degraded=rung,
                            elapsed_s=time.perf_counter() - t0)
                    # Transient: walk the ladder, back off, retry —
                    # within the retry budget and the deadline.
                    if retries >= self.max_retries:
                        ten.c_rejected.inc()
                        return ServiceResult(
                            status="rejected", tenant=tenant,
                            error=f"{type(exc).__name__}: {exc} "
                                  f"(after {retries} retries)",
                            retries=retries, degraded=rung,
                            retry_after_s=self.governor.retry_after_s,
                            elapsed_s=time.perf_counter() - t0)
                    nxt = self._next_rung(rung, cfg)
                    if nxt is not None:
                        rung = nxt
                        cfg = self._apply_rung(ten, rung, cfg)
                    else:
                        # Ladder exhausted for this config: stay on the
                        # deepest rung — reclaim again and retry until
                        # the retry budget runs out.
                        self.arena.reclaim()
                    delay = self._backoff_s(retries)
                    if deadline is not None:
                        remaining = deadline - time.perf_counter()
                        if remaining <= delay:
                            ten.c_timeouts.inc()
                            return ServiceResult(
                                status="timeout", tenant=tenant,
                                error=("deadline expired after %d "
                                       "retries" % retries),
                                retries=retries, degraded=rung,
                                elapsed_s=time.perf_counter() - t0)
                    time.sleep(delay)
                    retries += 1
                    ten.c_retries.inc()
                    continue

                # Success path.
                dt = time.perf_counter() - t_call
                if not was_hot:
                    self._calibrate_cold(ten, A, B, dt)
                if deadline is not None \
                        and time.perf_counter() > deadline:
                    # Completed, but past its budget: the client stopped
                    # waiting, so the contract says timeout — the warmed
                    # plan still benefits the next request.
                    ten.c_timeouts.inc()
                    return ServiceResult(
                        status="timeout", tenant=tenant,
                        error="completed after deadline",
                        retries=retries, degraded=rung,
                        elapsed_s=time.perf_counter() - t0)
                survived = (ten.engine.stats.faults_injected
                            - faults_before)
                if survived > 0:
                    ten.c_faults_survived.inc(survived)
                return ServiceResult(
                    status="ok", tenant=tenant, value=value,
                    retries=retries, degraded=rung,
                    elapsed_s=time.perf_counter() - t0,
                    faults_survived=survived)

    # -- batched sessions ----------------------------------------------------
    @contextlib.contextmanager
    def session(self, tenant: str = "default") -> Iterator["ServiceSession"]:
        """A batched client session: ``submit`` products, ``drain`` for
        results.  Holds the tenant's serialization lock for the whole
        session (sessions from different tenants run concurrently)."""
        ten = self._get_tenant(tenant)
        if ten is None:
            raise RuntimeError(
                f"tenant roster full ({self.max_tenants}); "
                f"cannot admit {tenant!r}")
        with ten.lock:
            yield ServiceSession(self, ten)

    # -- observability -------------------------------------------------------
    def prometheus_text(self) -> str:
        """One exposition document for the whole service: every tenant
        engine's samples under ``tenant="<name>"`` plus the service-wide
        registry.  This is what ``GET /metrics`` returns verbatim."""
        with self._lock:
            tenants = list(self._tenants.values())
        blocks = [engine_sample_blocks(t.engine, f'tenant="{t.name}"')
                  for t in tenants]
        blocks.append(self.registry.sample_blocks())
        return merge_sample_blocks(blocks)

    def serve_http(self, host: str = "127.0.0.1",
                   port: int = 0) -> "MetricsHTTPServer":
        """Start (or return the already-running) metrics endpoint.

        The check-then-create runs under ``_lock``: two threads racing
        here used to each start a listener and leak one (opslint LCK002).
        """
        with self._lock:
            if self._http is None:
                self._http = MetricsHTTPServer(self, host=host, port=port)
            return self._http

    def close(self) -> None:
        with self._lock:
            http, self._http = self._http, None
        if http is not None:
            http.close()  # join the server thread outside the lock


class ServiceSession:
    """Handle yielded by :meth:`SpgemmService.session` — thin, batched
    access to the tenant engine with service-grade pressure handling
    (drain retries once through an arena reclaim before giving up)."""

    def __init__(self, service: SpgemmService, tenant: _Tenant):
        self._service = service
        self._tenant = tenant

    def submit(self, A: CSR, B: CSR,
               config: Optional[SpgemmConfig] = None) -> int:
        return self._tenant.engine.submit(A, B, config)

    def drain(self, **kw) -> Dict[int, SpgemmResult]:
        try:
            return self._tenant.engine.drain(**kw)
        except ArenaPressureError:
            # The engine already reaped everything it had in flight;
            # reclaim idle leases service-wide and retry once.
            self._service.arena.reclaim()
            return self._tenant.engine.drain(**kw)


# ---------------------------------------------------------------------------
# Stdlib HTTP metrics endpoint.
# ---------------------------------------------------------------------------

class _MetricsHandler(BaseHTTPRequestHandler):
    service: SpgemmService  # set by the server subclass

    def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler API
        if self.path in ("/metrics", "/"):
            body = self.server.service.prometheus_text().encode()
            ctype = "text/plain; version=0.0.4; charset=utf-8"
        elif self.path == "/healthz":
            body = b"ok\n"
            ctype = "text/plain; charset=utf-8"
        else:
            self.send_error(404)
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):  # silence per-request stderr spam
        pass


class _Server(ThreadingHTTPServer):
    daemon_threads = True
    service: SpgemmService


class MetricsHTTPServer:
    """Background-thread HTTP endpoint serving a service's metrics.

    ``GET /metrics`` returns :meth:`SpgemmService.prometheus_text`;
    ``GET /healthz`` returns ``ok``.  ``port=0`` binds an ephemeral
    port (tests); :attr:`url` is the scrape address.
    """

    def __init__(self, service: SpgemmService, *,
                 host: str = "127.0.0.1", port: int = 0):
        self._server = _Server((host, port), _MetricsHandler)
        self._server.service = service
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="opsparse-metrics",
            daemon=True)
        self._thread.start()

    @property
    def port(self) -> int:
        return self._server.server_address[1]

    @property
    def url(self) -> str:
        host = self._server.server_address[0]
        return f"http://{host}:{self.port}/metrics"

    def close(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=5)
