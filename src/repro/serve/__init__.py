"""Serving front-ends: the LM batching loop (``engine``) and the
fault-tolerant multi-tenant SpGEMM service (``spgemm_service``)."""
from .engine import Request, ServingEngine
from .spgemm_service import (MetricsHTTPServer, ServiceResult,
                             ServiceSession, SpgemmService)

__all__ = [
    "Request", "ServingEngine",
    "MetricsHTTPServer", "ServiceResult", "ServiceSession", "SpgemmService",
]
