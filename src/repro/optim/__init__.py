from .adamw import (AdamWConfig, OptState, abstract_opt_state,
                    adamw_update, clip_by_global_norm, global_norm,
                    init_opt_state, lr_schedule)

__all__ = ["AdamWConfig", "OptState", "abstract_opt_state", "adamw_update",
           "clip_by_global_norm", "global_norm", "init_opt_state",
           "lr_schedule"]
