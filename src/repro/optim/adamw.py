"""AdamW with f32 master weights + global-norm clipping (pure JAX).

Optimizer state (m, v, master) shares the parameters' sharding; under the
train-mode FSDP+TP rules this is fully sharded (ZeRO-3-equivalent) — XLA
SPMD inserts the reduce-scatter / all-gather schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Tree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: Tree          # f32, params sharding
    v: Tree          # f32, params sharding
    master: Tree     # f32 master copy of the (bf16) params
    step: jax.Array  # () int32


def init_opt_state(params: Tree) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        m=jax.tree_util.tree_map(f32, params),
        v=jax.tree_util.tree_map(f32, params),
        master=jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params),
        step=jnp.zeros((), jnp.int32),
    )


def abstract_opt_state(abstract_p: Tree) -> OptState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return OptState(
        m=jax.tree_util.tree_map(f32, abstract_p),
        v=jax.tree_util.tree_map(f32, abstract_p),
        master=jax.tree_util.tree_map(f32, abstract_p),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )


def lr_schedule(cfg: AdamWConfig, step) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Tree, max_norm: float) -> Tuple[Tree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(params: Tree, grads: Tree, state: OptState,
                 cfg: AdamWConfig) -> Tuple[Tree, OptState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, w):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        w = w - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * w)
        return w.astype(p.dtype), m, v, w

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_w = treedef.flatten_up_to(state.master)
    out = [upd(*args) for args in zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_w = jax.tree_util.tree_unflatten(treedef, [o[3] for o in out])
    return new_p, OptState(new_m, new_v, new_w, step), {
        "grad_norm": gnorm, "lr": lr}
