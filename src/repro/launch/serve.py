"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Drives the continuous-batching engine on randomly generated requests
(reduced configs on CPU; the production mesh path is proven by dryrun.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.model import Model
from repro.serve.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced().replace(dtype="float32")
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=args.max_batch,
                           max_len=args.max_len)

    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        plen = int(rng.integers(4, 32))
        engine.submit(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab_size,
                                         plen).astype(np.int32),
            max_new_tokens=args.max_new_tokens))

    t0 = time.perf_counter()
    results = engine.run()
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in results.values())
    print(f"{cfg.name}: served {len(results)} requests / {total} tokens "
          f"in {dt:.1f}s ({total/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
