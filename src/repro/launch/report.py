"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run artifacts (results/dryrun/*.json) + the analytic estimator."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS, get_arch
from repro.launch import shapes as shp
from repro.launch.analytic import analytic_cell
from repro.launch.dryrun import MICROBATCHES

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _load(arch, shape, mesh):
    f = RESULTS / f"{arch}_{shape}_{mesh}.json"
    return json.loads(f.read_text()) if f.exists() else None


def _fmt_t(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def _advice(cfg, cell, a):
    b = a.bottleneck
    if cell == "train_4k":
        if b == "memory":
            return ("activation traffic dominates: fuse residual+norm, "
                    "larger microbatch when HBM allows")
        if b == "collective":
            return "overlap FSDP gathers with layer compute / widen TP"
        return "MXU-bound: raise per-chip batch or reduce remat recompute"
    if cell == "prefill_32k":
        return ("KV/activation streaming dominates: larger attention "
                "k-blocks, keep caches sharded on write"
                if b == "memory" else
                "TP activation reductions dominate: sequence-shard prefill")
    return ("weights+cache reads are the floor: quantize weights (int8), "
            "batch more sequences per chip" if b == "memory" else
            "per-layer TP reductions dominate: duplicate small weights")


def dryrun_table() -> str:
    rows = ["| arch | cell | mesh | compile | HLO flops/chip* | temp/dev | "
            "temp(TPU est) | args/dev | collectives present |",
            "|---|---|---|---|---|---|---|---|---|"]
    for arch in sorted(ARCHS):
        cfg = get_arch(arch)
        for cell in shp.cells_for(cfg):
            for mesh in ("16-16", "2-16-16"):
                art = _load(arch, cell, mesh)
                if art is None:
                    rows.append(f"| {arch} | {cell} | {mesh} | MISSING |")
                    continue
                ma = art["memory_analysis"]
                r = art["roofline"]
                colls = [k.replace("collective-permute", "cperm")
                         for k, v in r["coll_by_type"].items() if v > 0]
                rows.append(
                    f"| {arch} | {cell} | {mesh.replace('-', 'x')} | "
                    f"{art['compile_s']}s | {r['flops']:.2e} | "
                    f"{ma.get('temp_size_in_bytes', 0)/2**30:.1f}G | "
                    f"{ma.get('temp_tpu_estimate_bytes', 0)/2**30:.1f}G | "
                    f"{ma.get('argument_size_in_bytes', 0)/2**30:.1f}G | "
                    f"{','.join(colls) or '-'} |")
    return "\n".join(rows)


def roofline_table() -> str:
    rows = ["| arch | cell | t_comp | t_mem | t_coll | bottleneck | "
            "MODEL_FLOPS | useful/issued | MFU(roofline) | "
            "what moves the dominant term |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for arch in sorted(ARCHS):
        cfg = get_arch(arch)
        mb = MICROBATCHES.get(arch, 4)
        for cell in shp.cells_for(cfg):
            a = analytic_cell(cfg, cell, multi_pod=False, microbatches=mb)
            rows.append(
                f"| {arch} | {cell} | {_fmt_t(a.t_compute)} | "
                f"{_fmt_t(a.t_memory)} | {_fmt_t(a.t_collective)} | "
                f"**{a.bottleneck}** | {a.model_flops:.2e} | "
                f"{a.useful_ratio:.2f} | {a.mfu:.3f} | "
                f"{_advice(cfg, cell, a)} |")
    return "\n".join(rows)


def consistency_check() -> str:
    """HLO-vs-analytic: HLO flops ~= one scan body; analytic per-layer
    marginal should bracket it."""
    lines = ["| arch/cell | HLO flops/chip | analytic issued/chip | "
             "analytic/HLO (≈ trip count) |", "|---|---|---|---|"]
    for arch, cell in (("internlm2-1.8b", "prefill_32k"),
                       ("qwen3-1.7b", "decode_32k"),
                       ("falcon-mamba-7b", "decode_32k")):
        art = _load(arch, cell, "16-16")
        if art is None:
            continue
        cfg = get_arch(arch)
        a = analytic_cell(cfg, cell)
        hlo = art["roofline"]["flops"]
        lines.append(f"| {arch}/{cell} | {hlo:.2e} | "
                     f"{a.flops_issued:.2e} | {a.flops_issued/hlo:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print("## Dry-run table\n")
    print(dryrun_table())
    print("\n## Roofline table (single-pod 16x16)\n")
    print(roofline_table())
    print("\n## HLO-vs-analytic consistency\n")
    print(consistency_check())
