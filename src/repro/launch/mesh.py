"""Production meshes (per brief §MULTI-POD DRY-RUN).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  The 512-device host-platform override belongs
to ``dryrun.py`` ONLY (its first two lines) — tests and benches see the
single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The data-parallel axes of a production mesh ('pod'+'data')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out


def make_host_mesh(model_axis: int = 1):
    """A tiny mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
