"""Production meshes (per brief §MULTI-POD DRY-RUN).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  The 512-device host-platform override belongs
to ``dryrun.py`` ONLY (its first two lines) — tests and benches see the
single real CPU device.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The data-parallel axes of a production mesh ('pod'+'data')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def data_axis_devices(mesh) -> tuple:
    """One device per data-parallel slot of the mesh, in axis order.

    The model axes are collapsed to their first column: a row-sharded
    SpGEMM operand (shard s of A) lands on the s-th data slot, while B is
    replicated.  This is the placement surface the partition-aware engine
    uses (``repro.engine.partition``).
    """
    devs = np.asarray(mesh.devices)
    axes = data_axes(mesh)
    for i, name in enumerate(mesh.axis_names):
        if name not in axes:
            devs = np.take(devs, [0], axis=i)
    return tuple(devs.flatten())


def dp_size(mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out


def make_host_mesh(model_axis: int = 1):
    """A tiny mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
