"""Assigned input-shape cells and their abstract (ShapeDtypeStruct) inputs.

Per the brief: ``train_4k`` lowers ``train_step``; ``prefill_32k`` lowers
``serve_prefill``; ``decode_32k`` / ``long_500k`` lower ``serve_decode``
(one new token against a seq_len KV cache).  Skips (recorded in DESIGN.md
§Arch-applicability): encoder archs have no decode step; pure
full-attention archs skip ``long_500k``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import Model


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cells_for(cfg: ArchConfig) -> List[str]:
    cells = ["train_4k", "prefill_32k"]
    if cfg.is_encoder:
        return cells                   # encoder-only: no decode step
    cells.append("decode_32k")
    if cfg.sub_quadratic:
        cells.append("long_500k")      # quadratic-attention archs skip
    return cells


def _dt(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def abstract_batch(cfg: ArchConfig, cell: ShapeCell):
    """Train/prefill batch as ShapeDtypeStructs (no allocation)."""
    b, s = cell.global_batch, cell.seq_len
    if cfg.is_encoder:
        batch = {"features": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                  _dt(cfg))}
        if cell.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return batch
    s_tok = s + 1 if cell.kind == "train" else s
    batch = {"tokens": jax.ShapeDtypeStruct((b, s_tok), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), _dt(cfg))
    return batch


def abstract_decode_inputs(cfg: ArchConfig, cell: ShapeCell):
    """(token, caches, pos) ShapeDtypeStructs for a decode cell: one new
    token with a seq_len cache."""
    b, s = cell.global_batch, cell.seq_len
    model = Model(cfg)
    caches = model.init_caches(b, s, abstract=True)
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return token, caches, pos


def tokens_per_step(cfg: ArchConfig, cell: ShapeCell) -> int:
    if cell.kind == "decode":
        return cell.global_batch
    return cell.global_batch * cell.seq_len
