"""Roofline-term extraction from compiled dry-run artifacts (brief §ROOFLINE).

    compute    = HLO_FLOPs   / (chips * 197e12  bf16 FLOP/s)
    memory     = HLO_bytes   / (chips * 819e9   B/s HBM)
    collective = coll_bytes  / (chips * 50e9    B/s ICI per link)

``cost_analysis()`` provides FLOPs / bytes-accessed; collective bytes are
NOT in cost_analysis, so we parse the compiled HLO text and sum the operand
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction (operands carry their own typed shapes in
HLO text, e.g. ``all-reduce(f32[512]{0} %add.5)``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# -- TPU v5e hardware constants (per brief) ---------------------------------
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")


def _bytes_of_shapes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_rhs(rhs: str):
    """RHS of an HLO instruction: 'TYPE opcode(operands), attrs'.
    TYPE may be a tuple '(f32[..], ...)'.  Returns (type_str, opcode,
    operand_str) or None."""
    rhs = rhs.strip()
    if rhs.startswith("("):            # tuple type
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str, rest = rhs[:i + 1], rhs[i + 1:]
                    break
        else:
            return None
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp:]
    rest = rest.strip()
    par = rest.find("(")
    if par < 0:
        return None
    opcode = rest[:par].strip()
    args = rest[par + 1:]
    depth, end = 1, len(args)
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return type_str, opcode, args[:end]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum OPERAND bytes per collective kind from compiled HLO text.

    Two passes: (1) symbol table name -> result bytes from every
    instruction's declared type; (2) for each collective, sum its operands'
    bytes (by name lookup, falling back to inline-typed operands).
    ``*-done`` ops are skipped (their ``*-start`` twin already counted).
    """
    sizes: Dict[str, int] = {}
    instrs = []
    for line in hlo_text.splitlines():
        m = _NAME_RE.match(line)
        if not m:
            continue
        parsed = _split_rhs(m.group(2))
        if parsed is None:
            continue
        type_str, opcode, operand_str = parsed
        sizes[m.group(1)] = _bytes_of_shapes(type_str)
        instrs.append((opcode, operand_str))

    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for opcode, operand_str in instrs:
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base not in COLLECTIVE_OPS or opcode.endswith("-done"):
            continue
        names = _OPERAND_NAME_RE.findall(operand_str)
        if names:
            out[base] += sum(sizes.get(n, 0) for n in names)
        else:
            inline = _bytes_of_shapes(operand_str)
            if inline:
                out[base] += inline
            else:   # operands printed bare (no % and no types)
                toks = [t.strip() for t in operand_str.split(",")]
                out[base] += sum(sizes.get(t, 0) for t in toks)
    return out


@dataclasses.dataclass
class RooflineTerms:
    """All byte/FLOP quantities are PER-CHIP: ``cost_analysis()`` and
    ``as_text()`` describe the per-partition SPMD module (verified against
    a controlled sharded-matmul experiment)."""

    flops: float               # per-chip HLO FLOPs
    hbm_bytes: float           # per-chip bytes accessed
    coll_bytes: float          # per-chip collective operand bytes
    chips: int
    coll_by_type: Dict[str, int]
    model_flops: float = 0.0   # GLOBAL 6·N·D (train) / 2·N·D (serve)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline-optimistic step time = max of the three terms
        (perfect overlap assumption)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs — catches remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_roofline(self) -> float:
        """MODEL_FLOPS / (chips · peak · step_time): the roofline-implied
        hardware utilization on useful math — the §Perf score."""
        t = self.step_time
        return (self.model_flops / (self.chips * PEAK_FLOPS * t)) if t else 0.0

    def to_json(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "coll_by_type": self.coll_by_type,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_roofline": self.mfu_roofline,
        }


def cpu_bf16_dup_bytes(hlo_text: str) -> int:
    """CPU-backend artifact estimator: XLA CPU has no native bf16 dot, so
    it converts operands to f32 and HOISTS loop-invariant converts of
    weights/caches out of the scan loops — inflating temp by an f32 copy
    of every bf16 dot operand.  TPU's MXU consumes bf16 natively, so these
    copies do not exist on the target.  We count, per bf16 PARAMETER shape
    that also appears as an f32 tensor anywhere in the module, one f32
    copy per parameter instruction; ``temp - dup`` approximates the
    TPU-relevant temp footprint (reported alongside the raw number)."""
    f32_dims = set(re.findall(r"f32\[([0-9,]+)\]", hlo_text))
    dup = 0
    for line in hlo_text.splitlines():
        m = _NAME_RE.match(line)
        if not m:
            continue
        parsed = _split_rhs(m.group(2))
        if parsed is None or parsed[1] != "parameter":
            continue
        for dt, dims in _SHAPE_RE.findall(parsed[0]):
            if dt == "bf16" and dims in f32_dims:
                n = 1
                for d in dims.split(","):
                    n *= int(d)
                dup += 4 * n
    return dup


def cost_metric(cost, key: str) -> float:
    """cost_analysis() may return a dict or a 1-elem list of dicts."""
    if cost is None:
        return 0.0
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return float(cost.get(key, 0.0))


def terms_from_compiled(compiled, *, chips: int,
                        model_flops: float = 0.0) -> RooflineTerms:
    cost = compiled.cost_analysis()
    flops = cost_metric(cost, "flops")
    hbm = cost_metric(cost, "bytes accessed")
    coll = collective_bytes(compiled.as_text())
    return RooflineTerms(
        flops=flops, hbm_bytes=hbm, coll_bytes=float(sum(coll.values())),
        chips=chips, coll_by_type=coll, model_flops=model_flops)


# -- model FLOPs (6·N·D convention, non-embedding, MoE-active) ---------------

def _count(specs, pred) -> int:
    import math
    from repro.models.param import ParamSpec
    import jax
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(math.prod(ps.shape) for ps in leaves if pred(ps))


def model_flops_params(cfg, specs) -> Dict[str, float]:
    """N_total, N_nonemb (no vocab-axis params), N_active (MoE top-k)."""
    total = _count(specs, lambda ps: True)
    emb = _count(specs, lambda ps: "vocab" in ps.axes)
    expert = _count(specs, lambda ps: "experts" in ps.axes)
    nonemb = total - emb
    active = nonemb
    if cfg.num_experts:
        active = nonemb - expert * (1 - cfg.experts_per_token
                                    / cfg.num_experts)
    return {"total": float(total), "nonemb": float(nonemb),
            "active": float(active)}


def model_flops_for_cell(cfg, specs, kind: str, tokens: int) -> float:
    n = model_flops_params(cfg, specs)["active"]
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
