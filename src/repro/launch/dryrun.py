import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
#   init).  512 placeholder host devices build the production meshes; this
#   override lives ONLY here — tests/benches see the single real device.

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, get_arch          # noqa: E402
from repro.models.hints import activation_mesh     # noqa: E402
from repro.models.model import Model               # noqa: E402
from repro.optim import AdamWConfig                # noqa: E402
from repro.launch.mesh import make_production_mesh, data_axes  # noqa: E402
from repro.launch import shapes as shp             # noqa: E402
from repro.launch import sharding as shd           # noqa: E402
from repro.launch.steps import (TrainState, abstract_train_state,  # noqa: E402
                                make_decode_step, make_prefill_step,
                                make_train_step)
from repro.launch.roofline import (model_flops_for_cell,  # noqa: E402
                                   terms_from_compiled)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _named(tree, mesh):
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), tree,
        is_leaf=lambda x: isinstance(x, P))


def _bf16_arg_bytes(*aval_sharding_pairs) -> int:
    """Per-device bf16 argument bytes: sum of per-shard sizes over all
    bf16 leaves of the given (aval_tree, named_sharding_tree) pairs."""
    import numpy as np
    total = 0
    for avals, shardings in aval_sharding_pairs:
        flat_a = jax.tree_util.tree_leaves(avals)
        flat_s = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
        if len(flat_s) != len(flat_a):
            flat_s = [None] * len(flat_a)
        for a, s in zip(flat_a, flat_s):
            if str(getattr(a, "dtype", "")) != "bfloat16":
                continue
            shape = tuple(a.shape)
            if isinstance(s, NamedSharding):
                shape = s.shard_shape(shape)
            total += 2 * int(np.prod(shape)) if shape else 2
    return total


def _mem_fields(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    if not out and ma is not None:
        out["repr"] = str(ma)
    return out


# Gradient-accumulation factor per arch (keeps train_4k activations inside
# the 16 GB/chip HBM budget; chosen from the memory_analysis sweep).
MICROBATCHES = {
    "falcon-mamba-7b": 8, "hubert-xlarge": 2, "qwen3-1.7b": 4,
    "minitron-4b": 4, "internlm2-1.8b": 4, "codeqwen1.5-7b": 4,
    "zamba2-1.2b": 8, "olmoe-1b-7b": 4, "qwen3-moe-30b-a3b": 4,
    "llama-3.2-vision-90b": 16,
}

# Gather-once FSDP (§Perf iteration 2): viable for archs whose TP-sharded
# bf16 param copy fits next to activations; llama-90b's 11 GiB copy does
# not.  OFF by default — the recorded sweep is the paper-faithful baseline;
# pass --opt (or gather_once=True) for the optimized variants.
GATHER_ONCE_OK = {a: a != "llama-3.2-vision-90b" for a in MICROBATCHES}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               microbatches: int | None = None, gather_once: bool = False,
               overrides: dict | None = None, quantize: bool = False):
    """Lower + compile one (arch x shape x mesh) cell; return artifacts.

    ``gather_once`` / ``overrides`` / ``quantize`` (int8 weight-only
    serving) select the beyond-baseline optimizations recorded in
    EXPERIMENTS.md §Perf.
    """
    cfg = get_arch(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    cell = shp.SHAPES[shape_name]
    if shape_name not in shp.cells_for(cfg):
        raise ValueError(f"{arch} skips {shape_name} (see DESIGN.md)")
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    model = Model(cfg)
    specs = model.param_specs()

    t0 = time.perf_counter()
    with mesh, activation_mesh(mesh):
        if cell.kind == "train":
            rules = shd.train_rules(mesh)
            param_ps = shd.param_pspecs(specs, rules, mesh)
            state_ps = TrainState(
                params=param_ps,
                opt=type(abstract_train_state(model).opt)(
                    m=param_ps, v=param_ps, master=param_ps, step=P()))
            state = abstract_train_state(model)
            batch = shp.abstract_batch(cfg, cell)
            batch_ps = shd.batch_pspecs(cfg, batch, mesh, cell.global_batch)
            mb = microbatches or MICROBATCHES.get(arch, 4)
            # each microbatch must still fill the data axes
            dp_sz = mesh.size // mesh.shape["model"]
            mb = max(1, min(mb, cell.global_batch // dp_sz))
            gather_specs = None
            if gather_once and GATHER_ONCE_OK.get(arch, False):
                gather_specs = shd.param_pspecs(
                    specs, shd.serve_rules(mesh), mesh)
            fn = make_train_step(model, AdamWConfig(), microbatches=mb,
                                 gather_specs=gather_specs)
            jitted = jax.jit(
                fn,
                in_shardings=(_named(state_ps, mesh), _named(batch_ps, mesh)),
                out_shardings=(_named(state_ps, mesh), None),
                donate_argnums=(0,))
            lowered = jitted.lower(state, batch)
            bf16_pairs = [(state, _named(state_ps, mesh))]
        elif cell.kind == "prefill":
            rules = shd.serve_rules(mesh)
            param_ps = shd.param_pspecs(specs, rules, mesh)
            params = model.abstract_params()
            batch = shp.abstract_batch(cfg, cell)
            batch_ps = shd.batch_pspecs(cfg, batch, mesh, cell.global_batch)
            fn = make_prefill_step(model, kv_cache_len=cell.seq_len)
            caches_out_ps = None
            if not cfg.is_encoder:
                ab_caches = model.init_caches(cell.global_batch,
                                              cell.seq_len, abstract=True)
                caches_out_ps = shd.cache_pspecs(
                    cfg, ab_caches, mesh, global_batch=cell.global_batch,
                    seq_len=cell.seq_len)
            out_ps = (None, _named(caches_out_ps, mesh)
                      if caches_out_ps is not None else None)
            jitted = jax.jit(
                fn,
                in_shardings=(_named(param_ps, mesh), _named(batch_ps, mesh)),
                out_shardings=out_ps)
            lowered = jitted.lower(params, batch)
            bf16_pairs = [(params, _named(param_ps, mesh))]
        else:  # decode
            rules = shd.serve_rules(mesh)
            param_ps = shd.param_pspecs(specs, rules, mesh)
            params = model.abstract_params()
            if quantize:   # int8 weight-only serving (models/quant.py)
                from repro.models.quant import (abstract_quantized,
                                                quant_pspecs)
                param_ps = quant_pspecs(param_ps, params)
                params = abstract_quantized(params)
            token, caches, pos = shp.abstract_decode_inputs(cfg, cell)
            cache_ps = shd.cache_pspecs(
                cfg, caches, mesh, global_batch=cell.global_batch,
                seq_len=cell.seq_len)
            dp = data_axes(mesh)
            dp = dp if len(dp) > 1 else dp[0]
            b_ok = cell.global_batch % mesh.size // mesh.shape["model"] == 0
            tok_ps = shd.batch_pspecs(cfg, {"t": token}, mesh,
                                      cell.global_batch)["t"]
            fn = make_decode_step(model)
            jitted = jax.jit(
                fn,
                in_shardings=(_named(param_ps, mesh),
                              NamedSharding(mesh, tok_ps),
                              _named(cache_ps, mesh),
                              NamedSharding(mesh, P())),
                out_shardings=(NamedSharding(mesh, tok_ps), None,
                               _named(cache_ps, mesh)),
                donate_argnums=(2,))
            lowered = jitted.lower(params, token, caches, pos)
            bf16_pairs = [(params, _named(param_ps, mesh)),
                          (caches, _named(cache_ps, mesh))]

        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mf = model_flops_for_cell(cfg, specs, cell.kind,
                              shp.tokens_per_step(cfg, cell))
    terms = terms_from_compiled(compiled, chips=chips, model_flops=mf)
    mem = _mem_fields(compiled)
    # CPU-backend artifact correction: XLA CPU has no native bf16 dot — it
    # converts operands to f32 and hoists loop-invariant converts, so temp
    # carries an f32 copy (2x bytes) of ~every bf16 argument (weights, KV
    # caches).  TPU consumes bf16 natively; we report temp minus that
    # estimated duplication alongside the raw number.
    bf16_args = _bf16_arg_bytes(*bf16_pairs)
    dup = 2 * bf16_args
    temp = mem.get("temp_size_in_bytes", 0)
    mem["cpu_bf16_dup_bytes_est"] = dup
    mem["temp_tpu_estimate_bytes"] = max(temp - min(dup, temp), 0)
    artifact = {
        "arch": arch, "shape": shape_name, "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "roofline": terms.to_json(),
    }
    return artifact, compiled


def run_cell(arch, shape_name, multi_pod, save=True, verbose=True,
             gather_once=False, overrides=None, tag_suffix="",
             quantize=False):
    tag = (f"{arch}|{shape_name}|{'2x16x16' if multi_pod else '16x16'}"
           f"{tag_suffix}")
    try:
        artifact, compiled = lower_cell(arch, shape_name,
                                        multi_pod=multi_pod,
                                        gather_once=gather_once,
                                        overrides=overrides,
                                        quantize=quantize)
    except Exception as e:
        print(f"[FAIL] {tag}: {e}")
        traceback.print_exc()
        return None
    if verbose:
        ma = artifact["memory_analysis"]
        r = artifact["roofline"]
        print(f"[ok] {tag} compile={artifact['compile_s']}s "
              f"flops={r['flops']:.3e} bytes={r['hbm_bytes']:.3e} "
              f"coll={r['coll_bytes']:.3e} bottleneck={r['bottleneck']} "
              f"mfu_roofline={r['mfu_roofline']:.3f} "
              f"temp={ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB/dev "
              f"temp_tpu~={ma.get('temp_tpu_estimate_bytes', 0)/2**30:.2f}"
              f"GiB arg={ma.get('argument_size_in_bytes', 0)/2**30:.2f}GiB")
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        name = (f"{arch}_{shape_name}_"
                f"{artifact['mesh'].replace('x', '-')}{tag_suffix}.json")
        (RESULTS_DIR / name).write_text(json.dumps(artifact, indent=1))
    return artifact


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None,
                    help="architecture id (default: all)")
    ap.add_argument("--shape", default=None,
                    help="shape cell (default: all applicable)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(ARCHS)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_arch(arch)
        cells = [args.shape] if args.shape else shp.cells_for(cfg)
        for cell in cells:
            for mp in meshes:
                art = run_cell(arch, cell, mp)
                if art is None:
                    n_fail += 1
                else:
                    n_ok += 1
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
