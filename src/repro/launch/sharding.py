"""Sharding policies: logical-axis rules -> shape-checked PartitionSpecs.

Modes:
  * train — FSDP(+pod) × TP: params/opt-state sharded over BOTH the data
    axes (via the 'embed' logical axis) and the model axis (vocab / heads /
    ffn / experts / ssm-inner).  ZeRO-3-equivalent; XLA inserts per-layer
    all-gathers inside the scan-over-layers loop.
  * serve — TP only: params replicated over data axes (no per-step weight
    gathers on the latency path), activations/batch over data.

Every assignment is divisibility-checked against the mesh (e.g. hubert's
vocab=504 cannot shard 16-way -> replicated) and duplicate mesh axes within
one param are dropped.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import AttnCache
from repro.models.param import ParamSpec
from repro.models.ssm import SSMCache
from .mesh import data_axes

Tree = Any


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return math.prod(mesh.shape[a] for a in axes)


def train_rules(mesh: Mesh) -> Dict[str, Any]:
    fsdp = data_axes(mesh)
    fsdp = fsdp if len(fsdp) > 1 else fsdp[0]
    return {
        "vocab": "model", "embed": fsdp, "qkv": "model", "kv": "model",
        "mlp": "model", "inner": "model", "ssm_heads": "model",
        "experts": "model", "expert_mlp": None, "layers": None,
    }


def serve_rules(mesh: Mesh) -> Dict[str, Any]:
    return {
        "vocab": "model", "embed": None, "qkv": "model", "kv": "model",
        "mlp": "model", "inner": "model", "ssm_heads": "model",
        "experts": "model", "expert_mlp": None, "layers": None,
    }


def checked_pspec(shape, axes, rules, mesh: Mesh) -> P:
    """Apply rules with divisibility + duplicate-axis checks."""
    used = set()
    out = []
    for dim, logical in zip(shape, axes):
        assign = rules.get(logical) if logical is not None else None
        if assign is None:
            out.append(None)
            continue
        names = (assign,) if isinstance(assign, str) else tuple(assign)
        if any(n in used for n in names) or dim % _axis_size(mesh, names):
            out.append(None)
            continue
        used.update(names)
        out.append(assign)
    return P(*out)


def param_pspecs(specs: Tree, rules, mesh: Mesh) -> Tree:
    return jax.tree_util.tree_map(
        lambda ps: checked_pspec(ps.shape, ps.axes, rules, mesh),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def to_named(tree: Tree, mesh: Mesh) -> Tree:
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def batch_pspecs(cfg: ArchConfig, batch: Dict[str, Any], mesh: Mesh,
                 global_batch: int) -> Dict[str, P]:
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    b_axis = dp if global_batch % _axis_size(mesh, dp) == 0 else None
    out = {}
    for k, v in batch.items():
        out[k] = P(b_axis, *([None] * (len(v.shape) - 1)))
    return out


def cache_pspecs(cfg: ArchConfig, caches: Tree, mesh: Mesh, *,
                 global_batch: int, seq_len: int) -> Tree:
    """Shape-checked cache shardings.

    Preference order per KV cache: batch over the data axes; KV heads over
    'model' when divisible, else the sequence axis over 'model' (needed by
    kv<TP archs like llama-90b whose 32k cache would not fit replicated).
    For B=1 long-context decode the sequence axis additionally shards over
    'data' (sequence parallelism).  SSM states shard their channel/head dim
    over 'model'.
    """
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    dp_size = _axis_size(mesh, dp)
    model_size = mesh.shape["model"]
    b_axis = dp if global_batch % dp_size == 0 and global_batch >= dp_size \
        else None
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def attn_leaf(leaf_shape) -> P:
        lead = len(leaf_shape) - 4
        kv_ok = kvh % model_size == 0 and kvh >= model_size
        s_axis = None
        kv_axis = "model" if kv_ok else None
        if not kv_ok and leaf_shape[-3] % model_size == 0:
            s_axis = "model"
        seq_data = None
        if b_axis is None and leaf_shape[-3] % dp_size == 0 \
                and s_axis != dp and dp != "model":
            seq_data = dp   # B=1: sequence parallelism over data
        s_final = s_axis if s_axis else seq_data
        return P(*([None] * lead), b_axis, s_final, kv_axis, None)

    def ssm_leaves(c: SSMCache):
        conv_lead = len(c.conv.shape) - 3
        h_lead = len(c.h.shape) - (4 if cfg.mamba_version == 2 else 3)
        di_ok = "model" if cfg.d_inner % model_size == 0 else None
        conv_p = P(*([None] * conv_lead), b_axis, None, di_ok)
        if cfg.mamba_version == 2:
            nh = cfg.d_inner // cfg.ssm_head_dim
            nh_ok = "model" if nh % model_size == 0 else None
            h_p = P(*([None] * h_lead), b_axis, nh_ok, None, None)
        else:
            h_p = P(*([None] * h_lead), b_axis, di_ok, None)
        return SSMCache(conv=conv_p, h=h_p)

    def map_cache(c):
        if isinstance(c, AttnCache):
            return AttnCache(k=attn_leaf(c.k.shape), v=attn_leaf(c.v.shape))
        if isinstance(c, SSMCache):
            return ssm_leaves(c)
        raise TypeError(type(c))

    return jax.tree_util.tree_map(
        map_cache, caches,
        is_leaf=lambda x: isinstance(x, (AttnCache, SSMCache)))
