"""Step factories: the functions the dry-run lowers and the trainers run."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import Model
from repro.optim import (AdamWConfig, OptState, abstract_opt_state,
                         adamw_update, init_opt_state)

Tree = Any


class TrainState(NamedTuple):
    params: Tree
    opt: OptState


def abstract_train_state(model: Model) -> TrainState:
    p = model.abstract_params()
    return TrainState(params=p, opt=abstract_opt_state(p))


def init_train_state(model: Model, key) -> TrainState:
    p = model.init(key)
    return TrainState(params=p, opt=init_opt_state(p))


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    microbatches: int = 1, gather_specs=None):
    """Train step with optional gradient accumulation.

    ``microbatches > 1`` scans over batch slices accumulating f32 grads
    (params-sharded, so the accumulator is ZeRO-sharded too).  This bounds
    live activations to one microbatch — the lever that keeps the 4k-train
    cells inside the 16 GB/chip HBM budget — and is the standard
    large-batch discipline at pod scale.

    ``gather_specs`` (a PartitionSpec tree, typically the TP-only serve
    rules): GATHER-ONCE FSDP — the FSDP-sharded params are all-gathered
    once per step before the microbatch loop instead of once per
    microbatch, cutting the dominant collective term of weight-heavy
    archs ~mb-fold at the cost of one gathered bf16 copy in HBM (§Perf
    iteration 2).  Only safe when params/TP fits alongside activations.
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch, remat=True),
            has_aux=True)(params)
        return loss, metrics, grads

    def train_step(state: TrainState, batch):
        loss_params = state.params
        if gather_specs is not None:
            loss_params = jax.lax.with_sharding_constraint(
                state.params, gather_specs)
        if microbatches == 1:
            loss, metrics, grads = grads_of(loss_params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def body(carry, mb):
                gsum, loss_sum = carry
                loss, metrics, grads = grads_of(loss_params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, loss_sum + loss), metrics

            (gsum, loss_sum), metrics = jax.lax.scan(
                body, (g0, jnp.float32(0)), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: (g / microbatches), gsum)
            loss = loss_sum / microbatches
            metrics = jax.tree_util.tree_map(lambda m: m.mean(), metrics)

        new_params, new_opt, opt_metrics = adamw_update(
            state.params, grads, state.opt, opt_cfg)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_prefill_step(model: Model, kv_cache_len: Optional[int] = None):
    def serve_prefill(params, batch):
        return model.prefill(params, batch, kv_cache_len=kv_cache_len)

    return serve_prefill


def make_decode_step(model: Model):
    def serve_decode(params, token, caches, pos):
        logits, new_caches = model.decode_step(params, token, caches, pos)
        next_token = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(
            jnp.int32)
        return next_token, logits, new_caches

    return serve_decode
