"""Closed-form roofline terms per (arch × shape × mesh) cell.

WHY THIS EXISTS: XLA's ``cost_analysis()`` counts a ``while``-loop body
ONCE — with scan-over-layers (and chunked SSM/attention/xent scans) the
HLO numbers under-count by the trip counts (verified: internlm2 prefill
HLO FLOPs == exactly one layer's worth).  The dry-run therefore proves
compilation/sharding/memory, while the roofline TERMS are derived here
from the model math (exact FLOP counting — we wrote the model, every
matmul is enumerable) and first-order byte/collective accounting tied to
the sharding policy in ``launch/sharding.py``.  HLO numbers are kept as a
consistency check (per-layer marginal ≈ HLO body cost).

All outputs are PER-CHIP quantities for the 16x16 (or 2x16x16) mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig
from repro.models.model import Model
from .roofline import HBM_BW, ICI_BW, PEAK_FLOPS, model_flops_params
from .shapes import SHAPES, ShapeCell

BF16 = 2
F32 = 4


@dataclasses.dataclass
class AnalyticTerms:
    flops_issued: float     # per chip, incl. backward + remat recompute
    model_flops: float      # GLOBAL 6·N_active·D (train) / 2·N·D (serve)
    hbm_bytes: float        # per chip
    ici_bytes: float        # per chip
    chips: int
    notes: Dict[str, float]

    @property
    def t_compute(self):
        return self.flops_issued / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.ici_bytes / ICI_BW

    @property
    def step_time(self):
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def bottleneck(self):
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def mfu(self):
        """MODEL_FLOPS / (chips · peak · step_time) — the §Perf score."""
        st = self.step_time
        return self.model_flops / (self.chips * PEAK_FLOPS * st) if st else 0

    @property
    def useful_ratio(self):
        tot = self.flops_issued * self.chips
        return self.model_flops / tot if tot else 0


# -- forward FLOPs per TOKEN (global math, one layer) ------------------------

def _attn_flops_token(cfg: ArchConfig, s_att: float) -> float:
    h, hd, kvh, d = (cfg.num_heads, cfg.resolved_head_dim,
                     cfg.num_kv_heads, cfg.d_model)
    proj = 2 * d * (h + 2 * kvh) * hd + 2 * h * hd * d
    scores = 4 * s_att * h * hd          # QK^T + PV
    return proj + scores


def _mlp_flops_token(cfg: ArchConfig) -> float:
    if cfg.mlp_type == "none":
        return 0
    mults = 3 if cfg.mlp_type == "swiglu" else 2
    return 2 * mults * cfg.d_model * cfg.d_ff


def _moe_flops_token(cfg: ArchConfig) -> float:
    router = 2 * cfg.d_model * cfg.num_experts
    expert = 2 * 3 * cfg.d_model * cfg.d_ff
    return router + cfg.experts_per_token * cfg.moe_capacity_factor * expert


def _mamba1_flops_token(cfg: ArchConfig) -> float:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr = max(d // 16, 1)
    proj = 2 * d * 2 * di + 2 * di * (dtr + 2 * n) + 2 * dtr * di \
        + 2 * di * d
    conv = 2 * cfg.ssm_conv * di
    scan = 10 * di * n                  # dA, dBx, state update, y=C·h
    return proj + conv + scan


def _mamba2_flops_token(cfg: ArchConfig) -> float:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    q = cfg.ssm_chunk
    proj = 2 * d * 2 * di + 2 * di * 2 * n + 2 * di * nh + 2 * di * d
    conv = 2 * cfg.ssm_conv * di
    # SSD dual form per token: intra-chunk (Q-window attention-like) +
    # state carry terms.
    ssd = 2 * q * n + q * nh + 2 * q * di / 2 + 4 * di * n
    return proj + conv + ssd


def forward_flops_per_token(cfg: ArchConfig, s_att: float) -> float:
    """One-token forward FLOPs through the whole stack (+head)."""
    L = cfg.num_layers
    if cfg.family in ("dense", "encoder"):
        per_layer = _attn_flops_token(cfg, s_att) + _mlp_flops_token(cfg)
        body = L * per_layer
    elif cfg.family == "moe":
        per_layer = _attn_flops_token(cfg, s_att) + _moe_flops_token(cfg)
        body = L * per_layer
    elif cfg.family == "ssm":
        body = L * _mamba1_flops_token(cfg)
    elif cfg.family == "hybrid":
        n_shared = cfg.num_layers // cfg.attn_every
        body = (L * _mamba2_flops_token(cfg)
                + n_shared * (_attn_flops_token(cfg, s_att)
                              + _mlp_flops_token(cfg)))
    elif cfg.family == "vlm":
        n_cross = cfg.num_layers // cfg.cross_attn_every
        n_self = cfg.num_layers - n_cross
        cross = (_attn_flops_token(cfg, cfg.vision_tokens)
                 + _mlp_flops_token(cfg))
        body = (n_self * (_attn_flops_token(cfg, s_att)
                          + _mlp_flops_token(cfg)) + n_cross * cross)
    else:
        raise ValueError(cfg.family)
    head = 2 * cfg.d_model * cfg.vocab_size
    return body + head


_REMAT_MULT = {  # train total / forward: 1 fwd + 2 bwd + remat recompute
    "dense": 4.0, "encoder": 4.0, "moe": 4.0, "ssm": 4.0,
    "hybrid": 5.0, "vlm": 5.0,   # nested sqrt-L remat: one extra forward
}


# -- cache bytes -------------------------------------------------------------

def cache_bytes_global(cfg: ArchConfig, batch: int, seq: int) -> float:
    if cfg.family in ("dense", "moe", "encoder"):
        n_attn = cfg.num_layers
    elif cfg.family == "ssm":
        n_attn = 0
    elif cfg.family == "hybrid":
        n_attn = cfg.num_layers // cfg.attn_every
    elif cfg.family == "vlm":
        n_attn = cfg.num_layers      # self (4/5) + cross (vt) ~ upper bound
    attn = n_attn * 2 * batch * seq * cfg.num_kv_heads * \
        cfg.resolved_head_dim * BF16
    ssm = 0
    if cfg.ssm_state:
        di = cfg.d_inner
        if cfg.mamba_version == 2:
            state = (di // cfg.ssm_head_dim) * cfg.ssm_head_dim * \
                cfg.ssm_state
        else:
            state = di * cfg.ssm_state
        ssm = cfg.num_layers * batch * (state * F32
                                        + (cfg.ssm_conv - 1) * di * BF16)
    return attn + ssm


def _cache_shards(cfg: ArchConfig, batch: int, seq: int, dp: int,
                  tp: int) -> float:
    """How many ways the cache divides under the cache_pspecs policy."""
    shards = 1.0
    if batch % dp == 0 and batch >= dp:
        shards *= dp
    elif seq % dp == 0:      # B=1 long-context: sequence over data
        shards *= dp
    kvh = cfg.num_kv_heads
    if kvh and kvh % tp == 0 and kvh >= tp:
        shards *= tp
    elif seq % tp == 0:
        shards *= tp
    return shards


# -- the main entry ----------------------------------------------------------

def _reduces_per_layer(cfg: ArchConfig) -> float:
    """TP activation reductions per layer: Megatron counts 2 (attn out +
    mlp out); Mamba blocks have ONE row-parallel out_proj."""
    if cfg.family == "ssm":
        return 1.0
    if cfg.family == "hybrid":
        return (cfg.num_layers + 2 * (cfg.num_layers // cfg.attn_every)) \
            / cfg.num_layers
    return 2.0


def analytic_cell(cfg: ArchConfig, shape_name: str, *,
                  multi_pod: bool = False,
                  microbatches: int = 4,
                  gather_once: bool = False) -> AnalyticTerms:
    """``gather_once`` and the cfg knobs (moe_dispatch_dtype, attn_q_block)
    are the §Perf optimization levers; defaults = paper-faithful baseline."""
    cell = SHAPES[shape_name]
    tp = 16
    chips = 512 if multi_pod else 256
    dp = chips // tp
    B, S = cell.global_batch, cell.seq_len
    model = Model(cfg)
    n = model_flops_params(cfg, model.param_specs())
    W = n["total"] * BF16                      # param bytes (bf16)
    d = cfg.d_model
    V = cfg.vocab_size
    qb = cfg.attn_q_block
    disp_bytes = 1 if cfg.moe_dispatch_dtype == "int8" else BF16
    red = _reduces_per_layer(cfg)

    if cell.kind == "train":
        D = B * S
        s_att = S / 2                          # causal average
        fwd = forward_flops_per_token(cfg, s_att) * D
        issued = fwd * _REMAT_MULT[cfg.family] / chips
        model_fl = 6 * n["active"] * D

        mb = microbatches
        b_dev = B / dp / mb                    # sequences per chip per mb
        act = b_dev * S * d * BF16             # one residual tensor
        L = cfg.num_layers
        fsdp = 2 * (W / tp) if gather_once else mb * 3 * (W / tp)
        hbm = (
            fsdp                               # gathered-weight traffic
            + 2 * (W + 12 * n["total"]) / chips  # optimizer update
            + mb * L * 8 * act                 # per-layer activation traffic
            + 3 * (B / dp) * S * (V / tp) * F32  # chunked logits f+recompute
        )
        if S > 4096:                           # blocked attention KV re-reads
            hbm += mb * L * (S / qb) * b_dev * S * cfg.num_kv_heads * \
                cfg.resolved_head_dim * BF16 * 2
        ici = (
            fsdp                               # FSDP gathers + grad RS
            + mb * L * red * act               # TP activation reductions
        )
        if cfg.num_experts:
            cap_buf = (b_dev * S * cfg.experts_per_token
                       * cfg.moe_capacity_factor * d * disp_bytes)
            ici += mb * L * 2 * cap_buf        # EP dispatch/combine
            hbm += mb * L * 4 * cap_buf
        if multi_pod:
            ici += W / tp                      # cross-pod grad reduction
        notes = {"tokens": D, "fwd_flops_global": fwd}
        return AnalyticTerms(issued, model_fl, hbm, ici, chips, notes)

    if cell.kind == "prefill":
        D = B * S
        s_att = S / 2
        fwd = forward_flops_per_token(cfg, s_att) * D
        issued = fwd / chips
        model_fl = 2 * n["active"] * D
        b_dev = B / dp
        act = b_dev * S * d * BF16
        L = cfg.num_layers
        cache = cache_bytes_global(cfg, B, S) / _cache_shards(
            cfg, B, S, dp, tp)
        hbm = (W / tp + L * 8 * act + cache
               + (S / qb) * L * b_dev * S * cfg.num_kv_heads
               * cfg.resolved_head_dim * BF16 * 2
               + b_dev * (V / tp) * F32)
        ici = L * red * act + cache            # TP reductions + cache layout
        if cfg.num_experts:
            cap_buf = (b_dev * S * cfg.experts_per_token
                       * cfg.moe_capacity_factor * d * disp_bytes)
            ici += L * 2 * cap_buf
            hbm += L * 4 * cap_buf
        return AnalyticTerms(issued, model_fl, hbm, ici, chips,
                             {"tokens": D})

    # decode: one token per sequence against a seq_len cache
    D = B
    s_att = S
    fwd = forward_flops_per_token(cfg, s_att) * D
    issued = fwd / chips
    model_fl = 2 * n["active"] * D
    cache = cache_bytes_global(cfg, B, S) / _cache_shards(cfg, B, S, dp, tp)
    act = max(B / dp, 1) * d * BF16
    L = cfg.num_layers
    hbm = W / tp + cache + L * 8 * act + max(B / dp, 1) * (V / tp) * F32
    ici = L * red * act + max(B / dp, 1) * (V / tp) * F32
    if cfg.num_experts:
        cap = max(8, B / dp * cfg.experts_per_token
                  * cfg.moe_capacity_factor)
        ici += L * 2 * cap * d * BF16
    return AnalyticTerms(issued, model_fl, hbm, ici, chips,
                         {"tokens": D, "cache_bytes_chip": cache})
