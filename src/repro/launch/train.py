"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On a real pod this binary runs once per host under the cluster scheduler
(jax.distributed.initialize picks up the pod topology); in this container
it drives the same code path on the local device mesh.  The dry-run
(`dryrun.py`) is the multi-pod compile proof; this launcher is the
runnable end-to-end path (reduced configs on CPU).
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import get_arch
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.launch.steps import init_train_state, make_train_step
from repro.models.model import Model
from repro.models.param import param_count
from repro.optim import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (default: reduced())")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    cfg = get_arch(args.arch)
    if not args.full_size:
        cfg = cfg.reduced().replace(dtype="float32")
    model = Model(cfg)
    print(f"{cfg.name}: {param_count(model.param_specs())/1e6:.1f}M params")

    state = init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(
        model, AdamWConfig(lr=args.lr, total_steps=args.steps),
        microbatches=args.microbatches))
    data = SyntheticTokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))
    trainer = Trainer(step_fn, data, TrainerConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every))
    trainer.install_signal_handlers()
    state, step = trainer.fit(state)
    print(f"done at step {step}; last loss "
          f"{trainer.metrics_history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
