"""Mamba1 (selective scan) and Mamba2 (SSD, chunked dual form) layers.

TPU adaptation: the recurrence is computed CHUNKED over time — a sequential
``lax.scan`` over chunks carrying the SSM state, with a parallel
(associative-scan / matmul-dual) computation inside each chunk.  This keeps
the HBM-materialized state tensor at (B, chunk, ...) instead of (B, L, ...)
and turns the inner work into VPU/MXU-friendly batched ops.

  * Mamba1: per-channel state (d_inner, N).  In-chunk: associative scan.
  * Mamba2: per-head scalar decay (SSD).  In-chunk: the quadratic dual form
    (attention-like masked matmuls) + state carry — MXU-dominated.

Decode is a single-step state update (O(1) per token — why the long_500k
cell runs for ssm/hybrid archs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .hints import BATCH, TP, hint
from .param import spec


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SSMCache:
    conv: jax.Array    # (B, K-1, d_inner) — causal-conv tail
    h: jax.Array       # mamba1: (B, d_inner, N); mamba2: (B, nH, P, N)

    def tree_flatten(self):
        return (self.conv, self.h), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


# ---------------------------------------------------------------------------
# Shared: causal depthwise conv (kernel K) as shift-and-sum (shard-friendly)
# ---------------------------------------------------------------------------

def _causal_conv(x, w, b, tail: Optional[jax.Array] = None):
    """x: (B, L, D); w: (K, D); returns (B, L, D) and the new tail.

    tail: (B, K-1, D) previous inputs (decode/prefill continuation).
    """
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    ext = jnp.concatenate([tail, x], axis=1)          # (B, L+K-1, D)
    out = sum(ext[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    new_tail = ext[:, -(k - 1):, :]
    return out + b[None, None, :], new_tail


def _pad_chunks(q: int, x, dt, Bmat, Cmat):
    """Pad the time axis to a multiple of ``q`` with IDENTITY transitions:
    dt=0 gives dA=exp(0)=1 and dBx=0, so the carried state is untouched by
    padded steps; padded outputs are sliced off by the caller."""
    L = x.shape[1]
    pad = (-L) % q
    if pad:
        padt = lambda t: jnp.pad(
            t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        x, dt, Bmat, Cmat = map(padt, (x, dt, Bmat, Cmat))
    return x, dt, Bmat, Cmat, (L + pad) // q


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------

def mamba1_specs(cfg: ArchConfig):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = max(d // 16, 1)
    k = cfg.ssm_conv
    bt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "in_proj": spec((d, 2 * di), ("embed", "inner"), dtype=bt),
        "conv_w": spec((k, di), (None, "inner"), dtype=bt, scale=0.5),
        "conv_b": spec((di,), ("inner",), init="zeros", dtype=bt),
        "x_proj": spec((di, dt_rank + 2 * n), ("inner", None), dtype=bt),
        "dt_proj": spec((dt_rank, di), (None, "inner"), dtype=bt),
        "dt_bias": spec((di,), ("inner",), init="zeros", dtype=jnp.float32),
        "A_log": spec((di, n), ("inner", None), init="zeros", dtype=jnp.float32),
        "D": spec((di,), ("inner",), init="ones", dtype=jnp.float32),
        "out_proj": spec((di, d), ("inner", "embed"), dtype=bt),
    }


def _mamba1_scan_chunk(h, dA, dBx, C):
    """One chunk: h (B,D,N); dA/dBx (B,Q,D,N); C (B,Q,N) -> (h', y)."""
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_pref, b_pref = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    hs = a_pref * h[:, None] + b_pref                    # (B,Q,D,N)
    y = jnp.einsum("bqdn,bqn->bqd", hs, C)
    return hs[:, -1], y


def mamba1(p, u, cfg: ArchConfig, cache: Optional[SSMCache] = None
           ) -> Tuple[jax.Array, Optional[SSMCache]]:
    """u: (B, L, d_model).  cache given => decode (L == 1)."""
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = max(d // 16, 1)
    bsz, L, _ = u.shape

    xz = u @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)
    x = hint(x, BATCH, None, TP)
    z = hint(z, BATCH, None, TP)
    tail = cache.conv if cache is not None else None
    x, new_tail = _causal_conv(x, p["conv_w"], p["conv_b"], tail)
    x = jax.nn.silu(x)

    proj = x @ p["x_proj"]
    dt_raw = proj[..., :dt_rank]
    Bmat = proj[..., dt_rank:dt_rank + n].astype(jnp.float32)
    Cmat = proj[..., dt_rank + n:].astype(jnp.float32)
    dt = hint(jax.nn.softplus(
        (dt_raw @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"]),
        BATCH, None, TP)
    A = -jnp.exp(p["A_log"])                             # (D, N)
    xf = x.astype(jnp.float32)

    if cache is not None:                                # decode: one step
        dA = jnp.exp(dt[:, 0, :, None] * A[None])        # (B,D,N)
        dBx = (dt[:, 0, :, None] * Bmat[:, 0, None, :]
               * xf[:, 0, :, None])
        h = cache.h * dA + dBx
        y = jnp.einsum("bdn,bn->bd", h, Cmat[:, 0])[:, None]
        y = y + xf * p["D"][None, None]
        out = (y.astype(u.dtype) * jax.nn.silu(z)) @ p["out_proj"]
        return out, SSMCache(conv=new_tail, h=h)

    q = min(cfg.ssm_chunk, L)
    # Ragged tail: pad with identity transitions (dt=0 -> dA=1, dBx=0).
    xf, dt, Bmat, Cmat, nc = _pad_chunks(q, xf, dt, Bmat, Cmat)

    def chunk_step(h, inp):
        xq, dtq, bq, cq = inp                            # (B,Q,...)
        dA = jnp.exp(dtq[..., None] * A[None, None])     # (B,Q,D,N)
        dBx = dtq[..., None] * bq[:, :, None, :] * xq[..., None]
        h, y = _mamba1_scan_chunk(h, dA, dBx, cq)
        return h, y

    rs = lambda t: t.reshape(bsz, nc, q, *t.shape[2:]).swapaxes(0, 1)
    h0 = cache.h if cache is not None else jnp.zeros((bsz, di, n), jnp.float32)
    hL, ys = jax.lax.scan(chunk_step, h0,
                          (rs(xf), rs(dt), rs(Bmat), rs(Cmat)))
    y = ys.swapaxes(0, 1).reshape(bsz, nc * q, di)[:, :L]
    y = y + xf[:, :L] * p["D"][None, None]
    out = (y.astype(u.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, SSMCache(conv=new_tail, h=hL)


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

def mamba2_specs(cfg: ArchConfig):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    k = cfg.ssm_conv
    bt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "in_proj": spec((d, 2 * di), ("embed", "inner"), dtype=bt),
        "conv_w": spec((k, di), (None, "inner"), dtype=bt, scale=0.5),
        "conv_b": spec((di,), ("inner",), init="zeros", dtype=bt),
        "bc_proj": spec((di, 2 * n), ("inner", None), dtype=bt),
        "dt_proj": spec((di, nh), ("inner", "ssm_heads"), dtype=bt),
        "dt_bias": spec((nh,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "A_log": spec((nh,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "D": spec((nh,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "out_proj": spec((di, d), ("inner", "embed"), dtype=bt),
    }


def mamba2(p, u, cfg: ArchConfig, cache: Optional[SSMCache] = None
           ) -> Tuple[jax.Array, Optional[SSMCache]]:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    ph = cfg.ssm_head_dim
    nh = di // ph
    bsz, L, _ = u.shape

    xz = u @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)
    x = hint(x, BATCH, None, TP)
    z = hint(z, BATCH, None, TP)
    tail = cache.conv if cache is not None else None
    x, new_tail = _causal_conv(x, p["conv_w"], p["conv_b"], tail)
    x = jax.nn.silu(x)

    bc = x @ p["bc_proj"]
    Bmat = bc[..., :n].astype(jnp.float32)               # (B,L,N)
    Cmat = bc[..., n:].astype(jnp.float32)
    dt = jax.nn.softplus(
        (x @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])  # (B,L,nh)
    a = -jnp.exp(p["A_log"])                             # (nh,)
    xh = hint(x.astype(jnp.float32).reshape(bsz, L, nh, ph),
              BATCH, None, TP, None)

    if cache is not None:                                # decode step
        dtq = dt[:, 0]                                   # (B,nh)
        da = jnp.exp(dtq * a[None])                      # (B,nh)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dtq, Bmat[:, 0], xh[:, 0])
        h = cache.h * da[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", h, Cmat[:, 0])
        y = y + xh[:, 0] * p["D"][None, :, None]
        y = y.reshape(bsz, 1, di)
        out = (y.astype(u.dtype) * jax.nn.silu(z)) @ p["out_proj"]
        return out, SSMCache(conv=new_tail, h=h)

    q = min(cfg.ssm_chunk, L)
    xh, dt, Bmat, Cmat, nc = _pad_chunks(q, xh, dt, Bmat, Cmat)

    def chunk_step(h, inp):
        xq, dtq, bq, cq = inp                            # (B,Q,·)
        la = dtq * a[None, None]                         # (B,Q,nh) log-decay
        cum = jnp.cumsum(la, axis=1)                     # (B,Q,nh)
        # Intra-chunk dual form: masked attention-like matmul.
        g = jnp.einsum("bqn,bsn->bqs", cq, bq)           # (B,Q,Q)
        dec = jnp.exp(cum[:, :, None] - cum[:, None, :])  # (B,Q,S,nh)
        tri = jnp.tril(jnp.ones((q, q), bool))
        mmat = jnp.where(tri[None, :, :, None],
                         g[..., None] * dec * dtq[:, None], 0.0)
        y_intra = jnp.einsum("bqsh,bshp->bqhp", mmat, xq)
        # Inter-chunk: contribution of the carried state.
        y_inter = jnp.einsum("bqn,bhpn->bqhp", cq, h) * \
            jnp.exp(cum).transpose(0, 1, 2)[..., None]
        # State update.
        tail_dec = jnp.exp(cum[:, -1:, :] - cum)         # (B,Q,nh)
        dbx = jnp.einsum("bsh,bsn,bshp->bhpn", tail_dec * dtq, bq, xq)
        h = h * jnp.exp(cum[:, -1])[:, :, None, None] + dbx
        return h, y_intra + y_inter

    rs = lambda t: t.reshape(bsz, nc, q, *t.shape[2:]).swapaxes(0, 1)
    h0 = cache.h if cache is not None else \
        jnp.zeros((bsz, nh, ph, n), jnp.float32)
    hL, ys = jax.lax.scan(chunk_step, h0,
                          (rs(xh), rs(dt), rs(Bmat), rs(Cmat)))
    y = ys.swapaxes(0, 1).reshape(bsz, nc * q, nh, ph)[:, :L]
    y = y + xh[:, :L] * p["D"][None, None, :, None]
    y = y.reshape(bsz, L, di)
    out = (y.astype(u.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, SSMCache(conv=new_tail, h=hL)


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype):
    di, n = cfg.d_inner, cfg.ssm_state
    k = cfg.ssm_conv
    if cfg.mamba_version == 2:
        nh = di // cfg.ssm_head_dim
        h = jnp.zeros((batch, nh, cfg.ssm_head_dim, n), jnp.float32)
    else:
        h = jnp.zeros((batch, di, n), jnp.float32)
    return SSMCache(conv=jnp.zeros((batch, k - 1, di), dtype), h=h)


def abstract_ssm_cache(cfg: ArchConfig, batch: int, dtype):
    di, n = cfg.d_inner, cfg.ssm_state
    k = cfg.ssm_conv
    if cfg.mamba_version == 2:
        nh = di // cfg.ssm_head_dim
        h = jax.ShapeDtypeStruct((batch, nh, cfg.ssm_head_dim, n), jnp.float32)
    else:
        h = jax.ShapeDtypeStruct((batch, di, n), jnp.float32)
    return SSMCache(conv=jax.ShapeDtypeStruct((batch, k - 1, di), dtype), h=h)
