"""Parameter-spec machinery: one source of truth for shapes, dtypes and
LOGICAL sharding axes.

Every model builds a tree of ``ParamSpec`` (shape, dtype, logical axes).
From that single tree we derive:
  * materialized parameters (``init_params``),
  * ShapeDtypeStructs for the dry-run (``abstract_params`` — no allocation),
  * ``PartitionSpec`` trees via logical-axis rules (``partition_specs``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: Any
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (or None)
    init: str = "normal"              # "normal" | "zeros" | "ones"
    scale: float = 1.0                # stddev multiplier for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape, axes, dtype=jnp.bfloat16, init="normal", scale=1.0) -> ParamSpec:
    return ParamSpec(tuple(int(s) for s in shape), dtype, tuple(axes), init,
                     scale)


def _materialize(ps: ParamSpec, key) -> jax.Array:
    if ps.init == "zeros":
        return jnp.zeros(ps.shape, ps.dtype)
    if ps.init == "ones":
        return jnp.ones(ps.shape, ps.dtype)
    fan_in = ps.shape[0] if len(ps.shape) > 1 else max(ps.shape[0], 1)
    std = ps.scale / math.sqrt(fan_in)
    return (jax.random.normal(key, ps.shape, jnp.float32) * std).astype(ps.dtype)


def init_params(specs, key) -> Any:
    """Materialize a spec tree into parameter arrays."""
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [_materialize(ps, k) for ps, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(specs) -> Any:
    """ShapeDtypeStruct tree — the dry-run's zero-allocation parameters."""
    return jax.tree_util.tree_map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, ps.dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def partition_specs(specs, rules: Dict[str, Optional[str | Tuple[str, ...]]]):
    """Logical axes -> PartitionSpec via a rules dict (e.g. {"mlp": "model"}).

    Unknown logical names map to None (replicated).
    """
    def one(ps: ParamSpec):
        return P(*[rules.get(a) if a is not None else None for a in ps.axes])

    return jax.tree_util.tree_map(
        one, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_count(specs) -> int:
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(math.prod(ps.shape) for ps in leaves)
