"""Int8 weight-only quantization for serving (PTQ).

Fixes the one genuinely weight-bound cell of the dry-run: llama-90b
decode_32k needs 11.1 GiB/chip of bf16 weights at TP=16 — over the v5e
budget with its KV cache.  Storing weights as int8 + per-layer f32 scales
halves+ the resident bytes (and the weight-read memory-roofline term);
dequantization happens per layer INSIDE the scan loop, so only one
layer's bf16 weights are live at a time.

``QTensor`` is a pytree, so quantized params flow through jit/scan/
sharding unchanged; scales carry a leading layer axis when the tensor is
a stacked layer parameter so ``lax.scan`` slices them consistently.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Tree = Any


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    q: jax.Array          # int8 payload
    scale: jax.Array      # f32, broadcastable to q.shape

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self):
        return self.q.shape

    def dequant(self, dtype=jnp.bfloat16):
        return (self.q.astype(jnp.float32) * self.scale).astype(dtype)


def _scale_shape(shape):
    """One scale per trailing MATRIX slice: (L, E, d, f) -> (L, E, 1, 1).
    Keeps stacked-layer/expert weights on independent grids (a single
    per-layer scale measurably degrades MoE logits)."""
    if len(shape) > 2:
        return tuple(shape[:-2]) + (1, 1)
    return ()


MIN_DIM = 128   # quantize only true weight matrices (both trailing dims
                # >= MIN_DIM): excludes stacked biases/conv taps whose
                # scalar scales would break lax.scan slicing, and tiny
                # tensors where int8 error is all overhead


def _quantizable(x, min_dim: int = MIN_DIM) -> bool:
    return (hasattr(x, "dtype") and x.dtype == jnp.bfloat16
            and x.ndim >= 2 and x.shape[-1] >= min_dim
            and x.shape[-2] >= min_dim)


def quantize_params(params: Tree, min_dim: int = MIN_DIM) -> Tree:
    """Quantize weight-matrix bf16 leaves to int8 (symmetric, one scale
    per trailing matrix slice)."""
    def one(x):
        if not _quantizable(x, min_dim):
            return x
        xf = x.astype(jnp.float32)
        red = (x.ndim - 2, x.ndim - 1) if x.ndim > 2 else None
        amax = jnp.max(jnp.abs(xf), axis=red, keepdims=x.ndim > 2)
        scale = jnp.maximum(amax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        return QTensor(q=q, scale=scale.astype(jnp.float32))

    return jax.tree_util.tree_map(one, params)


def abstract_quantized(abstract_params: Tree,
                       min_dim: int = MIN_DIM) -> Tree:
    """ShapeDtypeStruct tree of the quantized params (dry-run)."""
    def one(x):
        if not (str(getattr(x, "dtype", "")) == "bfloat16"
                and len(x.shape) >= 2 and x.shape[-1] >= min_dim
                and x.shape[-2] >= min_dim):
            return x
        return QTensor(
            q=jax.ShapeDtypeStruct(x.shape, jnp.int8),
            scale=jax.ShapeDtypeStruct(_scale_shape(x.shape), jnp.float32))
    return jax.tree_util.tree_map(one, abstract_params)


def quant_pspecs(pspecs: Tree, abstract_params: Tree) -> Tree:
    """PartitionSpecs for the quantized tree: payload keeps the original
    spec; scales are replicated (tiny)."""
    from jax.sharding import PartitionSpec as P

    def one(spec, x):
        if not (str(getattr(x, "dtype", "")) == "bfloat16"
                and len(x.shape) >= 2 and x.shape[-1] >= MIN_DIM
                and x.shape[-2] >= MIN_DIM):
            return spec
        n_scale = len(_scale_shape(x.shape))
        return QTensor(q=spec, scale=P(*([None] * n_scale)))

    return jax.tree_util.tree_map(one, pspecs, abstract_params)


def dequant_tree(p: Tree, dtype=jnp.bfloat16) -> Tree:
    """Materialize bf16 weights for one layer slice (no-op without
    QTensors)."""
    return jax.tree_util.tree_map(
        lambda x: x.dequant(dtype) if isinstance(x, QTensor) else x, p,
        is_leaf=lambda x: isinstance(x, QTensor))
