"""Unified model builder: one ``Model`` class drives all six families
(dense / encoder / ssm / hybrid / moe / vlm) from an ``ArchConfig``.

Layers are stacked and scanned (``jax.lax.scan``) so the HLO stays compact
for 100-layer archs; the layer body is rematerialized (``jax.checkpoint``)
in training.  Caches are pytrees with a leading layer axis scanned along
with the parameters.

Three entry points per model (what the dry-run lowers):
  * ``loss_fn(params, batch)``        — train forward + mean token xent.
  * ``prefill(params, batch)``        — full-sequence forward, returns the
                                        last-position logits + caches.
  * ``decode_step(params, token, caches, pos)`` — one token w/ caches.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import layers as L
from .hints import BATCH, hint
from . import moe as M
from .quant import dequant_tree
from . import ssm as S
from .param import ParamSpec, abstract_params, init_params, spec

Tree = Any


def _dt(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def stack_specs(tree: Tree, n: int) -> Tree:
    """Add a leading scanned 'layers' axis to every spec in the tree."""
    return jax.tree_util.tree_map(
        lambda ps: ParamSpec((n,) + ps.shape, ps.dtype,
                             ("layers",) + ps.axes, ps.init, ps.scale),
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def _block_specs(cfg: ArchConfig) -> Tree:
    """One decoder block: attn + (mlp | moe)."""
    s: Dict[str, Tree] = {
        "attn_norm": spec((cfg.d_model,), (None,), init="ones",
                          dtype=jnp.float32),
        "attn": L.attention_specs(cfg),
        "mlp_norm": spec((cfg.d_model,), (None,), init="ones",
                         dtype=jnp.float32),
    }
    if cfg.family == "moe":
        s["moe"] = M.moe_specs(cfg)
    else:
        s["mlp"] = L.mlp_specs(cfg)
    return s


def _ssm_block_specs(cfg: ArchConfig) -> Tree:
    mk = S.mamba2_specs if cfg.mamba_version == 2 else S.mamba1_specs
    return {
        "norm": spec((cfg.d_model,), (None,), init="ones", dtype=jnp.float32),
        "mamba": mk(cfg),
    }


def _cross_block_specs(cfg: ArchConfig) -> Tree:
    return {
        "attn_norm": spec((cfg.d_model,), (None,), init="ones",
                          dtype=jnp.float32),
        "attn": L.attention_specs(cfg, cross=True),
        "mlp_norm": spec((cfg.d_model,), (None,), init="ones",
                         dtype=jnp.float32),
        "mlp": L.mlp_specs(cfg),
        "gate_attn": spec((1,), (None,), init="zeros", dtype=jnp.float32),
        "gate_mlp": spec((1,), (None,), init="zeros", dtype=jnp.float32),
    }


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # Parameter specs
    # ------------------------------------------------------------------
    def param_specs(self) -> Tree:
        cfg = self.cfg
        specs: Dict[str, Tree] = {
            "final_norm": spec((cfg.d_model,), (None,), init="ones",
                               dtype=jnp.float32),
        }
        if cfg.family == "encoder":
            specs["head"] = spec((cfg.d_model, cfg.vocab_size),
                                 ("embed", "vocab"), dtype=_dt(cfg))
            specs["layers"] = stack_specs(_block_specs(cfg), cfg.num_layers)
            return specs

        specs["embed"] = L.embed_specs(cfg)
        if cfg.family in ("dense", "moe"):
            specs["layers"] = stack_specs(_block_specs(cfg), cfg.num_layers)
        elif cfg.family == "ssm":
            specs["layers"] = stack_specs(_ssm_block_specs(cfg),
                                          cfg.num_layers)
        elif cfg.family == "hybrid":
            specs["layers"] = stack_specs(_ssm_block_specs(cfg),
                                          cfg.num_layers)
            specs["shared"] = _block_specs(cfg)          # ONE shared block
        elif cfg.family == "vlm":
            n_cross = cfg.num_layers // cfg.cross_attn_every
            n_self = cfg.num_layers - n_cross
            specs["layers"] = stack_specs(_block_specs(cfg), n_self)
            specs["cross_layers"] = stack_specs(_cross_block_specs(cfg),
                                                n_cross)
        else:
            raise ValueError(cfg.family)
        return specs

    def init(self, key) -> Tree:
        return init_params(self.param_specs(), key)

    def abstract_params(self) -> Tree:
        return abstract_params(self.param_specs())

    # ------------------------------------------------------------------
    # Blocks
    # ------------------------------------------------------------------
    def _block(self, p, x, *, positions, causal, cache=None, cache_pos=None,
               kv_cache_len=None, return_kv=False):
        """Standard transformer block (dense/moe/encoder + hybrid shared)."""
        cfg = self.cfg
        p = dequant_tree(p)      # int8-serving: materialize ONE layer
        x = hint(x, BATCH, None, None)
        h, new_cache = L.attention(
            p["attn"], L.rmsnorm(x, p["attn_norm"], cfg.norm_eps), cfg,
            positions=positions, causal=causal, cache=cache,
            cache_pos=cache_pos, kv_cache_len=kv_cache_len,
            return_kv=return_kv)
        x = x + h
        hi = L.rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
        if "moe" in p:
            out, aux = M.moe(p["moe"], hi, cfg)
        else:
            out, aux = L.mlp(p["mlp"], hi, cfg), jnp.float32(0)
        return x + out, new_cache, aux

    def _ssm_block(self, p, x, cache=None):
        cfg = self.cfg
        p = dequant_tree(p)      # int8-serving: materialize ONE layer
        x = hint(x, BATCH, None, None)
        fn = S.mamba2 if cfg.mamba_version == 2 else S.mamba1
        h, new_cache = fn(p["mamba"], L.rmsnorm(x, p["norm"], cfg.norm_eps),
                          cfg, cache=cache)
        return x + h, new_cache

    def _cross_block(self, p, x, vision_kv, *, positions):
        """VLM cross-attention block (gated, llama-3.2 style).

        ``vision_kv`` is either raw vision embeddings (B, Vt, d) at
        train/prefill or a static AttnCache at decode."""
        cfg = self.cfg
        p = dequant_tree(p)      # int8-serving: materialize ONE layer
        if isinstance(vision_kv, L.AttnCache):
            h, kv = L.attention(p["attn"],
                                L.rmsnorm(x, p["attn_norm"], cfg.norm_eps),
                                cfg, positions=positions, causal=False,
                                cache=vision_kv, cache_pos=None)
        else:
            h, kv = L.attention(p["attn"],
                                L.rmsnorm(x, p["attn_norm"], cfg.norm_eps),
                                cfg, positions=positions, causal=False,
                                kv_x=vision_kv, return_kv=True)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
        out = L.mlp(p["mlp"], L.rmsnorm(x, p["mlp_norm"], cfg.norm_eps), cfg)
        x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * out
        return x, kv

    # ------------------------------------------------------------------
    # Forward (shared by train / prefill / decode)
    # ------------------------------------------------------------------
    def _forward(self, params, x, *, positions, caches=None, cache_pos=None,
                 kv_cache_len=None, return_caches=False, remat=False,
                 vision=None):
        """x: (B, S, d) embedded inputs -> (hidden, new_caches, aux)."""
        cfg = self.cfg
        causal = not cfg.is_encoder
        decode = caches is not None and cache_pos is not None

        if cfg.family in ("dense", "moe", "encoder"):
            def step(x, lp, cache):
                x, nc, aux = self._block(
                    lp, x, positions=positions, causal=causal, cache=cache,
                    cache_pos=cache_pos, kv_cache_len=kv_cache_len,
                    return_kv=return_caches)
                return x, (nc, aux)

            x, (new_caches, auxs) = _scan_blocks(step, x, params["layers"],
                                                 caches, remat)
            return x, new_caches, jnp.sum(auxs)

        if cfg.family == "ssm":
            def step(x, lp, cache):
                x, nc = self._ssm_block(lp, x, cache)
                return x, nc

            x, new_caches = _scan_blocks(step, x, params["layers"], caches,
                                         remat)
            return x, new_caches, jnp.float32(0)

        if cfg.family == "hybrid":
            return self._forward_hybrid(
                params, x, positions=positions, caches=caches,
                cache_pos=cache_pos, kv_cache_len=kv_cache_len,
                return_caches=return_caches, remat=remat)

        if cfg.family == "vlm":
            return self._forward_vlm(
                params, x, positions=positions, caches=caches,
                cache_pos=cache_pos, kv_cache_len=kv_cache_len,
                return_caches=return_caches, remat=remat, vision=vision)

        raise ValueError(cfg.family)

    def _forward_hybrid(self, params, x, *, positions, caches, cache_pos,
                        kv_cache_len, return_caches, remat):
        """Zamba2-style: groups of `attn_every` mamba2 layers, each followed
        by ONE SHARED attention+MLP block; trailing mamba layers last."""
        cfg = self.cfg
        g = cfg.attn_every
        n_groups = cfg.num_layers // g
        n_main = n_groups * g
        shared = params["shared"]

        main = jax.tree_util.tree_map(
            lambda a: a[:n_main].reshape(n_groups, g, *a.shape[1:]),
            params["layers"])
        tail = jax.tree_util.tree_map(lambda a: a[n_main:], params["layers"])

        if caches is None:
            ssm_main = ssm_tail = attn_caches = None
        else:
            ssm_main, ssm_tail, attn_caches = caches

        def inner_step(x, lp, cache):
            x, nc = self._ssm_block(lp, x, cache)
            return x, nc

        def group_step(x, gp, gcaches):
            gssm, gattn = gcaches if gcaches is not None else (None, None)
            x, new_ssm = _scan_blocks(inner_step, x, gp, gssm, remat)
            x, new_attn, _ = self._block(
                shared, x, positions=positions, causal=True, cache=gattn,
                cache_pos=cache_pos, kv_cache_len=kv_cache_len,
                return_kv=return_caches)
            return x, (new_ssm, new_attn)

        # Nested (sqrt-L) remat: group boundaries AND layer bodies are both
        # checkpointed — residuals saved per group, recompute per layer.
        group_caches = None if ssm_main is None else (ssm_main, attn_caches)
        x, (new_ssm_main, new_attn) = _scan_blocks(
            group_step, x, main, group_caches, remat=remat)
        x, new_ssm_tail = _scan_blocks(inner_step, x, tail, ssm_tail, remat)
        return x, (new_ssm_main, new_ssm_tail, new_attn), jnp.float32(0)

    def _forward_vlm(self, params, x, *, positions, caches, cache_pos,
                     kv_cache_len, return_caches, remat, vision):
        """Llama-3.2-vision style: every `cross_attn_every`-th block is a
        gated cross-attention block over vision embeddings."""
        cfg = self.cfg
        e = cfg.cross_attn_every
        n_cross = cfg.num_layers // e
        g = e - 1                                    # self layers per group

        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape(n_cross, g, *a.shape[1:]), params["layers"])

        if caches is None:
            self_caches = cross_caches = None
        else:
            self_caches, cross_caches = caches
        vision_src = cross_caches if cross_caches is not None else vision

        def inner_step(x, lp, cache):
            x, nc, _ = self._block(
                lp, x, positions=positions, causal=True, cache=cache,
                cache_pos=cache_pos, kv_cache_len=kv_cache_len,
                return_kv=return_caches)
            return x, nc

        def group_step(x, gp_pair, gcaches):
            gp, cp = gp_pair
            gself, gcross = gcaches if gcaches is not None else (None, None)
            x, new_self = _scan_blocks(inner_step, x, gp, gself, remat)
            vsrc = gcross if gcross is not None else vision
            x, new_cross = self._cross_block(cp, x, vsrc,
                                             positions=positions)
            return x, (new_self, new_cross)

        # Nested (sqrt-L) remat — see _forward_hybrid.
        group_caches = (None if self_caches is None
                        else (self_caches, cross_caches))
        x, (new_self, new_cross) = _scan_blocks(
            group_step, x, (grouped, params["cross_layers"]), group_caches,
            remat=remat)
        return x, (new_self, new_cross), jnp.float32(0)

    # ------------------------------------------------------------------
    # Train loss
    # ------------------------------------------------------------------
    def loss_fn(self, params, batch, *, remat: bool = True):
        cfg = self.cfg
        if cfg.family == "encoder":
            x = batch["features"].astype(_dt(cfg))
            labels = batch["labels"]
            b, s = labels.shape
            positions = jnp.arange(s)[None]
            hidden, _, aux = self._forward(params, x, positions=positions,
                                           remat=remat)
            hidden = L.rmsnorm(hidden, params["final_norm"], cfg.norm_eps)
            loss = L.chunked_softmax_xent({"head": params["head"]}, hidden,
                                          labels)
            return loss, {"xent": loss}

        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        b, s = inputs.shape
        positions = jnp.arange(s)[None]
        x = L.embed(params["embed"], inputs).astype(_dt(cfg))
        vision = batch.get("vision")
        if vision is not None:
            vision = vision.astype(_dt(cfg))
        hidden, _, aux = self._forward(params, x, positions=positions,
                                       remat=remat, vision=vision)
        hidden = L.rmsnorm(hidden, params["final_norm"], cfg.norm_eps)
        xent = L.chunked_softmax_xent(params["embed"], hidden, labels)
        loss = xent + 0.01 * aux
        return loss, {"xent": xent, "aux": aux}

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def prefill(self, params, batch, *, kv_cache_len: Optional[int] = None):
        """Full-sequence forward; returns (last_logits, caches)."""
        cfg = self.cfg
        if cfg.family == "encoder":
            x = batch["features"].astype(_dt(cfg))
            s = x.shape[1]
            positions = jnp.arange(s)[None]
            hidden, _, _ = self._forward(params, x, positions=positions)
            hidden = L.rmsnorm(hidden, params["final_norm"], cfg.norm_eps)
            return hidden @ params["head"], None

        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.arange(s)[None]
        embed_p = dequant_tree(params["embed"])
        x = L.embed(embed_p, tokens).astype(_dt(cfg))
        vision = batch.get("vision")
        if vision is not None:
            vision = vision.astype(_dt(cfg))
        hidden, caches, _ = self._forward(
            params, x, positions=positions, return_caches=True,
            kv_cache_len=kv_cache_len or s, vision=vision)
        hidden = L.rmsnorm(hidden[:, -1:], params["final_norm"], cfg.norm_eps)
        return L.logits(embed_p, hidden), caches

    def decode_step(self, params, token, caches, pos):
        """token: (B, 1) int32; pos: () or (B,) int32 (per-slot positions,
        continuous batching) — returns (logits, caches)."""
        cfg = self.cfg
        assert cfg.family != "encoder", "encoder archs have no decode step"
        b = token.shape[0]
        pos = jnp.asarray(pos, jnp.int32)
        pos_vec = jnp.broadcast_to(pos.reshape(-1) if pos.ndim else pos, (b,))
        positions = pos_vec[:, None]
        embed_p = dequant_tree(params["embed"])
        x = L.embed(embed_p, token).astype(_dt(cfg))
        hidden, new_caches, _ = self._forward(
            params, x, positions=positions, caches=caches, cache_pos=pos)
        hidden = L.rmsnorm(hidden, params["final_norm"], cfg.norm_eps)
        return L.logits(embed_p, hidden), new_caches

    # ------------------------------------------------------------------
    # Cache construction
    # ------------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, *, abstract=False):
        cfg = self.cfg
        dt = _dt(cfg)

        def attn_cache():
            return L.init_attn_cache(cfg, batch, max_len, dt,
                                     abstract=abstract)

        def ssm_cache():
            if abstract:
                return S.abstract_ssm_cache(cfg, batch, dt)
            return S.init_ssm_cache(cfg, batch, dt)

        def stack(tree, n):
            def add_dim(x):
                if isinstance(x, jax.ShapeDtypeStruct):
                    return jax.ShapeDtypeStruct((n,) + x.shape, x.dtype)
                return jnp.broadcast_to(x[None], (n,) + x.shape)
            return jax.tree_util.tree_map(add_dim, tree)

        if cfg.family in ("dense", "moe", "encoder"):
            return stack(attn_cache(), cfg.num_layers)
        if cfg.family == "ssm":
            return stack(ssm_cache(), cfg.num_layers)
        if cfg.family == "hybrid":
            g = cfg.attn_every
            n_groups = cfg.num_layers // g
            n_tail = cfg.num_layers - n_groups * g
            return (stack(stack(ssm_cache(), g), n_groups),
                    stack(ssm_cache(), n_tail),
                    stack(attn_cache(), n_groups))
        if cfg.family == "vlm":
            e = cfg.cross_attn_every
            n_cross = cfg.num_layers // e
            g = e - 1
            vt = cfg.vision_tokens
            cross = L.init_attn_cache(cfg, batch, vt, dt, abstract=abstract)
            return (stack(stack(attn_cache(), g), n_cross),
                    stack(cross, n_cross))
        raise ValueError(cfg.family)


def _scan_blocks(step, x, params_stack, caches, remat: bool):
    """``lax.scan`` over stacked layer params (and caches, when given).

    ``step(x, layer_params, cache_or_None) -> (x, y)``.
    """
    if caches is None:
        def body(c, lp):
            return step(c, lp, None)
        xs = params_stack
    else:
        def body(c, inp):
            lp, cache = inp
            return step(c, lp, cache)
        xs = (params_stack, caches)
    if remat:
        body = jax.checkpoint(body)
    return jax.lax.scan(body, x, xs)
