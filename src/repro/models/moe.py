"""Mixture-of-Experts layer whose token dispatch IS the OpSparse binning.

Routing T tokens × top-k to E experts is the paper's two-pass binning
problem (DESIGN.md §4): histogram per-expert counts, exclusive-sum offsets,
stable counting-sort scatter of assignment ids into one flat array
(`core.binning.bin_by_id`).  The dispatch/combine are then sparse
gather/segment operations (the ESC accumulator's discipline) rather than
the dense one-hot einsum of reference MoE implementations — the dense
variant is kept as ``moe_dense_dispatch`` and benchmarked against it in
``benchmarks/bench_moe_dispatch.py``.

Experts are evaluated as grouped matmuls on an (E, C, d) capacity buffer —
MXU-friendly; E shards over the 'model' mesh axis (expert parallelism).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.binning import bin_by_id
from .hints import BATCH, TP, hint
from .param import spec


def moe_specs(cfg: ArchConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "router": spec((d, e), ("embed", None), dtype=jnp.float32),
        "w_gate": spec((e, d, f), ("experts", "embed", "expert_mlp"), dtype=dt),
        "w_up": spec((e, d, f), ("experts", "embed", "expert_mlp"), dtype=dt),
        "w_down": spec((e, f, d), ("experts", "expert_mlp", "embed"), dtype=dt),
    }


def _capacity(cfg: ArchConfig, tokens: int) -> int:
    cap = int(tokens * cfg.experts_per_token * cfg.moe_capacity_factor
              / cfg.num_experts)
    return max(8, -(-cap // 8) * 8)  # multiple of 8 (sublane alignment)


def route(p, x_flat, cfg: ArchConfig):
    """Router: top-k experts + normalized weights + load-balance aux loss."""
    logits = x_flat.astype(jnp.float32) @ p["router"]       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.maximum(
        weights.sum(-1, keepdims=True), 1e-9)               # (T, k)
    # Switch-style aux loss: E * sum_e fraction_e * mean_prob_e
    e = cfg.num_experts
    counts = jnp.zeros(e, jnp.float32).at[experts.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(counts.sum(), 1.0)
    aux = e * jnp.sum(frac * probs.mean(0))
    return weights, experts, aux


def moe(p, x, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).  GROUP-LOCAL binning dispatch.

    Each sequence is a dispatch group (the paper's thread-block analog):
    ``bin_by_id`` runs vmapped per group, so every gather/scatter index is
    group-local — SPMD shards the batched scatters over the data axes
    without the giant cross-shard index tensors a flat (B·S·k) dispatch
    induces (measured: −45 GiB/dev on olmoe train_4k), and capacity is
    per-group, which is how pod-scale MoE actually balances load.
    """
    b, s, d = x.shape
    k, e = cfg.experts_per_token, cfg.num_experts
    cap = _capacity(cfg, s)                                 # per group
    x = hint(x, BATCH, None, None)

    weights, experts, aux = route(p, x.reshape(b * s, d), cfg)
    weights = weights.reshape(b, s, k)
    assign = experts.reshape(b, s * k)                      # (B, S*k)

    # --- OpSparse two-pass binning, one instance per group ---------------
    order, counts, offsets = jax.vmap(
        lambda ids: bin_by_id(ids, e))(assign)
    sorted_e = jnp.take_along_axis(assign, order, axis=1)
    pos_in_e = jnp.arange(s * k, dtype=jnp.int32)[None] - \
        jnp.take_along_axis(offsets, sorted_e, axis=1)
    keep = pos_in_e < cap                                   # capacity drop
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)
    token_of = order // k                                   # (B, S*k) < S

    # Dispatch: group-local gather + batched scatter into (B, E*C, d).
    gathered = jnp.take_along_axis(
        x, token_of[..., None].astype(jnp.int32), axis=1)   # (B, S*k, d)
    quant = cfg.moe_dispatch_dtype == "int8"
    if quant:
        # Per-token symmetric int8 quantization of the dispatch payload:
        # the buffer crossing the expert-parallel axis carries int8 + one
        # f32 scale per slot instead of bf16 — ~2x less ICI traffic on the
        # dominant MoE collective (see EXPERIMENTS.md §Perf).
        g32 = gathered.astype(jnp.float32)
        g_scale = jnp.maximum(jnp.max(jnp.abs(g32), axis=-1,
                                      keepdims=True) / 127.0, 1e-12)
        gathered = jnp.clip(jnp.round(g32 / g_scale), -127,
                            127).astype(jnp.int8)
        scale_buf = jax.vmap(
            lambda sc, sl: jnp.zeros((e * cap, 1), jnp.float32)
            .at[sl].set(sc, mode="drop"))(g_scale, slot)
    buf = jax.vmap(
        lambda g, sl: jnp.zeros((e * cap, d), g.dtype).at[sl].set(
            g, mode="drop"))(gathered, slot)
    hidden = hint(buf.reshape(b, e, cap, d), BATCH, TP, None, None)
    if quant:
        scales = hint(scale_buf.reshape(b, e, cap, 1), BATCH, TP, None, None)
        hidden = (hidden.astype(jnp.float32) * scales).astype(x.dtype)

    # Expert FFN (swiglu) — per-expert matmuls on the MXU, E over 'model'.
    gate = jax.nn.silu(jnp.einsum("becd,edf->becf", hidden, p["w_gate"]))
    up = jnp.einsum("becd,edf->becf", hidden, p["w_up"])
    out_buf = jnp.einsum("becf,efd->becd", gate * up, p["w_down"])
    out_flat = hint(out_buf.reshape(b, e * cap, d), BATCH, None, None)

    # Combine: gather outputs back per assignment, weight, segment-sum.
    safe_slot = jnp.minimum(slot, e * cap - 1)
    contrib = jnp.take_along_axis(out_flat, safe_slot[..., None], axis=1)
    contrib = jnp.where(keep[..., None], contrib, 0)
    w_sorted = jnp.take_along_axis(
        weights.reshape(b, s * k), order, axis=1)[..., None].astype(x.dtype)
    out = jax.vmap(
        lambda t_of, c: jnp.zeros((s, d), x.dtype).at[t_of].add(c))(
        token_of, contrib * w_sorted)
    out = hint(out, BATCH, None, None)
    return out, aux


def moe_dense_dispatch(p, x, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """Reference dense one-hot dispatch (GShard-style einsum) — the
    baseline the binning dispatch is benchmarked against."""
    b, s, d = x.shape
    t = b * s
    k, e = cfg.experts_per_token, cfg.num_experts
    cap = _capacity(cfg, t)
    x_flat = x.reshape(t, d)
    weights, experts, aux = route(p, x_flat, cfg)

    onehot = jax.nn.one_hot(experts, e, dtype=jnp.float32)  # (T, k, E)
    # rank of each (token, slot) within its expert — cumsum over the
    # FLATTENED (T*k) assignment axis so different k-slots never collide
    flat = onehot.reshape(t * k, e)
    pos_f = jnp.cumsum(flat, axis=0) - flat
    pos = jnp.einsum("tke,tke->tk", pos_f.reshape(t, k, e), onehot)
    keep = pos < cap
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                            dtype=jnp.float32)              # (T, k, C)
    disp = jnp.einsum("tke,tkc->tec", onehot, pos_oh)       # (T, E, C)
    hidden = jnp.einsum("tec,td->ecd", disp, x_flat.astype(jnp.float32)
                        ).astype(x.dtype)
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", hidden, p["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", hidden, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", gate * up, p["w_down"])
    comb = jnp.einsum("tke,tkc,tk->tec", onehot, pos_oh,
                      weights.astype(jnp.float32))
    out = jnp.einsum("tec,ecd->td", comb, out_buf.astype(jnp.float32))
    return out.astype(x.dtype).reshape(b, s, d), aux
