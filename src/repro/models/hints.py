"""Activation sharding hints.

XLA SPMD propagation alone picks catastrophic layouts for FSDP-style
weight shardings: measured on internlm2 train_4k, it replicated the batch
dim and sharded heads instead (f32[256,1,4096,4096] score buffers → 81
GiB/dev).  Explicit per-activation constraints (the MaxText discipline) pin
batch to the data axes and heads/ffn/experts to the model axis.

``hint`` is a no-op unless the launcher installs a mesh via
``activation_mesh`` — tests and single-device code paths are unaffected.
Every assignment is divisibility-checked, so archs whose dims don't divide
the mesh (kv heads < TP, vocab 504, batch 1) degrade to replication
automatically.
"""
from __future__ import annotations

import contextlib
import math
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

BATCH = ("pod", "data")   # logical batch axes (present subset is used)
TP = "model"              # tensor-parallel axis
SEQ = "data"              # sequence-parallel axis (long-context decode)

_ACTIVE_MESH: Optional[object] = None


@contextlib.contextmanager
def activation_mesh(mesh):
    """Install the mesh used by ``hint`` for the duration of a trace."""
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        yield
    finally:
        _ACTIVE_MESH = prev


def current_mesh():
    return _ACTIVE_MESH


def axis_size(name: str) -> int:
    """Size of a mesh axis in the active mesh (1 when absent/no mesh)."""
    mesh = _ACTIVE_MESH
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def hint(x, *axes):
    """``with_sharding_constraint`` with divisibility/duplicate checks.

    ``axes`` entries: None, a mesh-axis name, or a tuple of names; entries
    referencing axes absent from the active mesh, non-divisible dims, or
    already-used mesh axes are dropped (replicated).
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    used = set()
    spec = []
    for dim, a in zip(x.shape, axes):
        if a is None:
            spec.append(None)
            continue
        names = (a,) if isinstance(a, str) else tuple(a)
        names = tuple(n for n in names if n in mesh.axis_names)
        if not names:
            spec.append(None)
            continue
        size = math.prod(mesh.shape[n] for n in names)
        if any(n in used for n in names) or dim % size or dim < size:
            spec.append(None)
            continue
        used.update(names)
        spec.append(names[0] if len(names) == 1 else names)
    return jax.lax.with_sharding_constraint(x, P(*spec))
