"""Transformer layer library: norms, RoPE, GQA attention (+KV cache),
MLPs, embeddings, chunked cross-entropy.

Pure functions over parameter dicts built from ``param.ParamSpec`` trees.
Compute in the config dtype (bf16 by default); normalizations, softmax and
loss accumulate in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .hints import BATCH, TP, hint
from .param import spec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_spec(d, name="scale"):
    return {name: spec((d,), (None,), init="ones", dtype=jnp.float32)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm, optional KV cache)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AttnCache:
    k: jax.Array          # (B, S_max, kvH, hd)
    v: jax.Array

    def tree_flatten(self):
        return (self.k, self.v), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def attention_specs(cfg: ArchConfig, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    s = {
        "wq": spec((d, h * hd), ("embed", "qkv"), dtype=dt),
        "wk": spec((d, kvh * hd), ("embed", "kv"), dtype=dt),
        "wv": spec((d, kvh * hd), ("embed", "kv"), dtype=dt),
        "wo": spec((h * hd, d), ("qkv", "embed"), dtype=dt),
    }
    if cfg.qk_norm and not cross:
        s["q_norm"] = spec((hd,), (None,), init="ones", dtype=jnp.float32)
        s["k_norm"] = spec((hd,), (None,), init="ones", dtype=jnp.float32)
    return s


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def _repeat_kv(k, h, hint_heads: bool = True):
    """Repeat KV heads up to ``h`` query heads.  Materializing the repeat
    keeps a SINGLE head dim of size h, which shards cleanly over the TP
    axis — the grouped 5-D formulation defeats SPMD head-sharding and
    replicates the (Sq, Sk) score tensor (measured: +30 GiB/dev at 4k).

    ``hint_heads=False`` for sequence-sharded KV caches (decode with
    kv_heads < TP): head-hinting there forces an involuntary cache
    rematerialization; instead the score contraction stays sequence-
    parallel (softmax collectives are tiny at Sq=1)."""
    kvh = k.shape[2]
    if kvh == h:
        return k
    rep = jnp.repeat(k, h // kvh, axis=2)
    if hint_heads:
        rep = hint(rep, BATCH, None, TP, None)
    return rep


def _gqa_scores(q, k, scale, hint_heads: bool = True):
    """q: (B,Sq,H,hd), k: (B,Sk,kvH,hd) -> (B,H,Sq,Sk)."""
    k = _repeat_kv(k, q.shape[2], hint_heads)
    return jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale


def _gqa_out(probs, v, hint_heads: bool = True):
    b, h, sq, sk = probs.shape
    v = _repeat_kv(v, h, hint_heads)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.reshape(b, sq, h * v.shape[-1])


def _blocked_attention(q, k, v, *, causal: bool, scale: float,
                       q_block: int = 1024, k_block: int = 1024):
    """Flash-style blocked attention (pure JAX, scan-of-scan).

    Never materializes the (Sq, Sk) score matrix — peak per-step buffers
    are (B, kvH, G, q_block, k_block).  Required for the 32k-prefill cells
    (an unblocked 32k x 32k score tensor is ~TBs).

    q: (B,Sq,H,hd); k/v: (B,Sk,kvH,hd) (repeated to H inside).
    """
    b, sq, h, hd = q.shape
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    sk = k.shape[1]
    qb = min(q_block, sq)
    kb = min(k_block, sk)
    # Ragged tails (e.g. 6400 vision tokens): pad keys/queries up to a
    # block multiple; padded keys are masked out, padded queries sliced off.
    sq_real, sk_real = sq, sk
    q_pad, k_pad = (-sq) % qb, (-sk) % kb
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
        sq += q_pad
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        sk += k_pad
    nq, nk = sq // qb, sk // kb

    qs = jnp.moveaxis(q.reshape(b, nq, qb, h, hd), 1, 0)
    ks = jnp.moveaxis(k.reshape(b, nk, kb, h, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nk, kb, h, hd), 1, 0)

    def q_step(_, qi_with_idx):
        qi, iq = qi_with_idx
        m0 = jnp.full((b, h, qb), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, qb), jnp.float32)
        a0 = jnp.zeros((b, h, qb, hd), jnp.float32)

        def k_step(carry, kj_with_idx):
            m, l, acc = carry
            kj, vj, jk = kj_with_idx
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj).astype(jnp.float32)
            s = s * scale
            kpos = jk * kb + jnp.arange(kb)
            msk = (kpos < sk_real)[None, :]
            if causal:
                qpos = iq * qb + jnp.arange(qb)
                msk = msk & (qpos[:, None] >= kpos[None, :])
            s = jnp.where(msk[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qi.dtype), vj).astype(jnp.float32)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (ks, vs, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.moveaxis(out, 2, 1)                     # (B,qb,H,hd)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, h * hd)
    return out[:, :sq_real]


BLOCKED_ATTN_THRESHOLD = 4096  # use flash-style blocking only above 4k


def attention(p, x, cfg: ArchConfig, *, positions, causal: bool = True,
              cache: Optional[AttnCache] = None,
              cache_pos=None,
              kv_x: Optional[jax.Array] = None,
              return_kv: bool = False,
              kv_cache_len: Optional[int] = None,
              use_rope: bool = True):
    """Self- or cross-attention.

    Modes:
      * full-sequence (train / prefill): ``cache=None``.  With
        ``return_kv=True`` also returns an ``AttnCache`` padded to
        ``kv_cache_len`` (prefill).
      * decode: ``cache`` + ``cache_pos`` given, x has S=1; k/v written at
        ``cache_pos``; attends over positions <= cache_pos.
      * static-cache cross-attention: ``cache`` given, ``cache_pos=None`` —
        attends over the whole cache, no update (vision KV at decode).
      * cross-attention from ``kv_x`` (no causal mask, no RoPE).
    """
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    scale = hd ** -0.5

    # Cached attention with kv_heads < TP runs SEQUENCE-parallel (cache
    # sharded on S, heads replicated): head-hinting q or the kv-repeat
    # there pushes a partial kv-head sharding through the score einsum and
    # SPMD "involuntarily rematerializes" (replicates) the cache.
    from .hints import axis_size
    kv_on_heads = kvh % axis_size(TP) == 0 and kvh >= axis_size(TP)
    seq_parallel_cache = cache is not None and not kv_on_heads

    q = _split_heads(x @ p["wq"], h, hd)
    if not seq_parallel_cache:
        q = hint(q, BATCH, None, TP, None)
    if cfg.qk_norm and "q_norm" in p:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    if use_rope and kv_x is None:
        q = rope(q, positions, cfg.rope_theta)

    if cache is not None and cache_pos is None:
        # Static cache (cross-attention at decode): full visibility.
        scores = _gqa_scores(q, cache.k, scale, hint_heads=kv_on_heads)
        if seq_parallel_cache:
            scores = hint(scores, BATCH, None, None, TP)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1
                               ).astype(x.dtype)
        out = _gqa_out(probs, cache.v, hint_heads=kv_on_heads)
        return out @ p["wo"], cache

    src = kv_x if kv_x is not None else x
    k = _split_heads(src @ p["wk"], kvh, hd)
    v = _split_heads(src @ p["wv"], kvh, hd)
    if cache is None:
        k = hint(k, BATCH, None, TP, None)
        v = hint(v, BATCH, None, TP, None)
    if cfg.qk_norm and "k_norm" in p:
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if use_rope and kv_x is None:
        k = rope(k, positions, cfg.rope_theta)

    if cache is not None:
        # Decode: write this token's k/v at the (per-slot) position and
        # attend over each slot's visible prefix.  ``cache_pos`` is ()
        # (aligned decode / dry-run) or (B,) (continuous batching).
        b = x.shape[0]
        pos_arr = jnp.asarray(cache_pos, jnp.int32)
        pos_vec = jnp.broadcast_to(pos_arr.reshape(-1) if pos_arr.ndim
                                   else pos_arr, (b,))
        bidx = jnp.arange(b)
        k_cache = cache.k.at[bidx, pos_vec].set(k[:, 0])
        v_cache = cache.v.at[bidx, pos_vec].set(v[:, 0])
        # Pin the updated cache to the layout it arrives in (kv-heads over
        # TP when divisible, else sequence over TP).
        if kv_on_heads:
            k_cache = hint(k_cache, BATCH, None, TP, None)
            v_cache = hint(v_cache, BATCH, None, TP, None)
        else:   # sequence-parallel cache (kv heads < TP)
            k_cache = hint(k_cache, BATCH, TP, None, None)
            v_cache = hint(v_cache, BATCH, TP, None, None)
        scores = _gqa_scores(q, k_cache, scale, hint_heads=kv_on_heads)
        if seq_parallel_cache:
            scores = hint(scores, BATCH, None, None, TP)
        keymask = jnp.arange(k_cache.shape[1])[None, :] <= pos_vec[:, None]
        scores = jnp.where(keymask[:, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1
                               ).astype(x.dtype)
        out = _gqa_out(probs, v_cache, hint_heads=kv_on_heads)
        return out @ p["wo"], AttnCache(k=k_cache, v=v_cache)

    sq, sk = q.shape[1], k.shape[1]
    if max(sq, sk) > BLOCKED_ATTN_THRESHOLD:
        out = _blocked_attention(q, k, v, causal=causal and kv_x is None,
                                 scale=scale, q_block=cfg.attn_q_block,
                                 k_block=cfg.attn_k_block)
    else:
        scores = _gqa_scores(q, k, scale)                   # (B,kvH,G,Sq,Sk)
        if causal and kv_x is None:
            mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
            scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1
                               ).astype(x.dtype)
        out = _gqa_out(probs, v)

    new_cache = None
    if return_kv:
        pad_to = kv_cache_len or sk
        if pad_to > sk:
            zk = jnp.zeros((k.shape[0], pad_to - sk, kvh, hd), k.dtype)
            k, v = (jnp.concatenate([k, zk], 1),
                    jnp.concatenate([v, zk], 1))
        new_cache = AttnCache(k=k, v=v)
    return out @ p["wo"], new_cache


def init_attn_cache(cfg: ArchConfig, batch: int, max_len: int, dtype,
                    abstract: bool = False):
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    mk = (jax.ShapeDtypeStruct if abstract else
          lambda s, d: jnp.zeros(s, d))
    return AttnCache(k=mk((batch, max_len, kvh, hd), dtype),
                     v=mk((batch, max_len, kvh, hd), dtype))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ArchConfig, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": spec((d, f), ("embed", "mlp"), dtype=dt),
            "w_up": spec((d, f), ("embed", "mlp"), dtype=dt),
            "w_down": spec((f, d), ("mlp", "embed"), dtype=dt),
        }
    return {
        "w_up": spec((d, f), ("embed", "mlp"), dtype=dt),
        "w_down": spec((f, d), ("mlp", "embed"), dtype=dt),
    }


def mlp(p, x, cfg: ArchConfig):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    h = hint(h, BATCH, None, TP)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Embedding + LM head + chunked cross-entropy
# ---------------------------------------------------------------------------

def embed_specs(cfg: ArchConfig):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "embedding": spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                          dtype=dt, scale=1.0),
        "head": spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                     dtype=dt),
    }


def embed(p, tokens):
    return jnp.take(p["embedding"], tokens, axis=0)


def logits(p, x):
    return x @ p["head"]


def chunked_softmax_xent(p, x, labels, *, chunk: int = 512,
                         label_mask=None) -> jax.Array:
    """Mean token cross-entropy, scanned over sequence chunks so the
    (B, S, V) logits tensor is never materialized (peak is (B, chunk, V));
    essential for 150k-vocab archs at seq 4k."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    n_chunks = s // chunk
    assert s % chunk == 0, (s, chunk)
    head = p["head"]
    if label_mask is None:
        label_mask = jnp.ones((b, s), bool)

    xcs = x.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    lcs = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    mcs = label_mask.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    @jax.checkpoint   # backward recomputes per-chunk logits (never stored)
    def body(carry, inp):
        xc, lc, mc = inp
        lg = hint((xc @ head).astype(jnp.float32), BATCH, None, TP)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lc[..., None], axis=-1)[..., 0]
        nll = jnp.where(mc, lse - gold, 0.0)
        return (carry[0] + nll.sum(), carry[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.int32(0)),
                                 (xcs, lcs, mcs))
    return tot / jnp.maximum(cnt, 1)
