"""Pallas TPU kernels: per-bin hash-table SpGEMM phases (OpSparse §5.2, §5.6).

One grid step computes ONE output row (kernel1..7 of the paper); the hash
table lives in VMEM scratch (the analog of the V100's 96 KB shared memory —
DESIGN.md §2/§5).  Row ids of the bin and the bin's row count arrive via
scalar prefetch; the CSR arrays stay in HBM (`pl.ANY`) and are loaded with
dynamic slices.

Probe disciplines (paper §5.2, Fig. 9):
  * ``single_access=True``  — Algorithms 4/5: ONE table transaction per
    probe iteration.  On GPU this is the swapped-`atomicCAS` trick; a Pallas
    grid step owns its row's table, so the same discipline is a single
    read-modify-write per iteration, no CAS needed.
  * ``single_access=False`` — the nsparse/spECK baseline: check-then-CAS,
    i.e. a second table transaction whenever an empty slot is claimed (and
    for the numeric phase an extra transaction on the value slot).

Both variants report per-row TABLE ACCESS COUNTS so the Fig. 9 reproduction
can compare transaction counts exactly rather than relying on interpret-mode
wall time.

Overflow routing: the orchestrator bins rows so that row size <= table_size
/ multiplier; rows larger than the top rung go straight to the ESC (HBM)
accumulator (`core/esc.py`) — the analog of the paper's global-memory hash
kernels (symbolic kernel8 / numeric kernel7).  Unlike the paper we never
try-and-recompute: for the symbolic phase n_prod >= n_nz bounds the table
occupancy a priori, so the direct route can never overflow (the paper's
0.8-threshold recompute exists because it bins by n_prod but sizes kernel7's
table optimistically).  A probe-count guard (2*t_size) still protects
against misuse.

Sorting/condensing (paper's numeric "condense + sort" phases): done as a
*vectorized epilogue* outside the kernel — per-row argsort over the dumped
tables.  On TPU, sorts vectorize on the VPU, whereas in-kernel scalar
condense loops would serialize; this is the hardware adaptation recorded in
DESIGN.md.

Fusion (paper opt. 2, taken one step further): the two-pass flow builds
every row's hash table TWICE — the symbolic phase counts it, the numeric
phase rebuilds it from scratch to accumulate values.  ``fused_bin_call``
builds the (col, val) table ONCE per row and emits nnz, the raw table, and
the per-row transaction count in one ``pallas_call``; the numeric result
reuses the symbolic build instead of re-probing, roughly halving per-row
table transactions (measured by the Fig.-9 access counters, not asserted).
The two-pass kernels stay as the parity/access-count oracle.

Row packing (paper opt. 3 trade-off, TPU form): a rung whose table is
smaller than the minimum (8, 128) int32 VMEM tile leaves most of the tile
idle when one grid step owns one row.  With ``row_packing`` the fused AND
standalone symbolic kernels pack ``ladder.rows_per_block[b]`` rows per grid
step as independent sub-tables inside one tile (per-sub-row offsets from
scalar prefetch), so rung occupancy scales with the tile instead of the
row.  The two-pass NUMERIC kernels stay unpacked: they dump their raw
tables, so packing would change the dumped stride for no occupancy win.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import esc
from repro.core.analysis import exclusive_sum_in_place
from repro.core.binning import Binning
from repro.core.binning_ranges import BinLadder
from repro.core.csr import CSR, gather_rows
from repro.core.workspace import next_bucket
from repro.kernels import resolve_interpret

HASH_SCALE = 107  # nsparse's multiplicative constant, kept (§5.2 "same way")
_PROBE_GUARD_FACTOR = 2  # safety: bail after 2*t_size probes (misuse guard)
_ROW_BUCKET_MIN = 8      # smallest per-rung row-count bucket

INT32_MAX = np.iinfo(np.int32).max


def _table_geom(t_size: int) -> Tuple[int, int]:
    """VMEM scratch geometry: lane-aligned (rows, 128)."""
    rows = max(1, -(-t_size // 128))
    return rows, 128


def _is_pow2(n: int) -> bool:
    return n & (n - 1) == 0


def _hash_init(key, t_size: int):
    if _is_pow2(t_size):
        return (key * HASH_SCALE) & (t_size - 1)
    return (key * HASH_SCALE) % t_size


def _hash_next(h, t_size: int):
    if _is_pow2(t_size):          # §5.2: logic-AND when pow2 (symbolic)
        return (h + 1) & (t_size - 1)
    return jnp.where(h + 1 < t_size, h + 1, 0)  # mod path (numeric)


# ---------------------------------------------------------------------------
# Symbolic kernel: count distinct column ids per row (no value multiply).
# ---------------------------------------------------------------------------

def _make_symbolic_kernel(t_size: int, pack: int, single_access: bool):
    t_rows, stride = _packed_geom(t_size, pack)
    guard = _PROBE_GUARD_FACTOR * t_size

    def kernel(rows_smem, count_smem, a_rpt, a_col, b_rpt, b_col,
               nnz_out, acc_out, table):
        i = pl.program_id(0)
        # One fresh tile per grid step (the paper re-initializes per thread
        # block); sub-row j owns [j*stride, j*stride + t_size) of the
        # flattened tile — identical to the fused kernel's packing.
        table[...] = jnp.full((t_rows, 128), -1, jnp.int32)

        for j in range(pack):           # static unroll over the sub-tables
            idx = i * pack + j
            active = idx < count_smem[0]
            r = rows_smem[idx]
            base = j * stride
            a_lo = jnp.where(active, a_rpt[r], 0)
            a_hi = jnp.where(active, a_rpt[r + 1], 0)

            def insert(key, carry, base=base):
                nnz, acc = carry
                h0 = _hash_init(key, t_size)

                def cond(st):
                    h, done, ins, probes = st
                    return (~done) & (probes < guard)

                if single_access:
                    def body(st):
                        h, done, ins, probes = st
                        slot = base + h
                        hr, hl = slot // 128, slot % 128
                        cur = table[hr, hl]                   # 1 transaction
                        empty = cur == -1
                        table[hr, hl] = jnp.where(empty, key, cur)
                        hit = empty | (cur == key)
                        return (_hash_next(h, t_size), hit, ins | empty,
                                probes + 1)
                else:
                    def body(st):
                        h, done, ins, probes = st
                        slot = base + h
                        hr, hl = slot // 128, slot % 128
                        cur = table[hr, hl]                   # transaction 1
                        empty = cur == -1
                        # nsparse-style: a separate CAS transaction claims
                        # the empty slot (read-again-and-write).
                        cur2 = jnp.where(empty, table[hr, hl], cur)  # 2
                        table[hr, hl] = jnp.where(empty, key, cur2)
                        hit = empty | (cur == key)
                        return (_hash_next(h, t_size), hit, ins | empty,
                                probes +
                                jnp.where(empty, 2, 1).astype(jnp.int32))

                h, done, ins, probes = jax.lax.while_loop(
                    cond, body, (h0, jnp.asarray(False), jnp.asarray(False),
                                 jnp.int32(0)))
                return nnz + ins.astype(jnp.int32), acc + probes

            def outer(e, carry):
                k = a_col[a_lo + e]
                b_lo = b_rpt[k]
                b_hi = b_rpt[k + 1]

                def inner(jj, carry):
                    c = b_col[b_lo + jj]
                    return insert(c, carry)

                return jax.lax.fori_loop(0, b_hi - b_lo, inner, carry)

            nnz, acc = jax.lax.fori_loop(0, a_hi - a_lo, outer,
                                         (jnp.int32(0), jnp.int32(0)))
            nnz_out[j] = jnp.where(active, nnz, 0)
            acc_out[j] = jnp.where(active, acc, 0)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("t_size", "rows_cap", "pack", "single_access",
                     "interpret"))
def symbolic_bin_call(rows, count, a_rpt, a_col, b_rpt, b_col, *,
                      t_size: int, rows_cap: int, pack: int = 1,
                      single_access: bool = True,
                      interpret: Optional[bool] = None):
    """Run the symbolic hash kernel over one bin.

    rows:  (rows_cap,) int32 row ids (padded); count: (1,) int32 valid rows.
    One grid step counts ``pack`` rows as sub-tables of one VMEM tile
    (``pack=1`` reproduces the one-row-per-step layout).
    Returns (nnz, accesses): both (rows_cap,) int32.
    """
    interpret = resolve_interpret(interpret)
    assert rows_cap % pack == 0, (rows_cap, pack)
    t_rows, _ = _packed_geom(t_size, pack)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(rows_cap // pack,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 4,
        out_specs=[
            pl.BlockSpec((pack,), lambda i, rows, cnt: (i,)),
            pl.BlockSpec((pack,), lambda i, rows, cnt: (i,)),
        ],
        scratch_shapes=[pltpu.VMEM((t_rows, 128), jnp.int32)],
    )
    kernel = _make_symbolic_kernel(t_size, pack, single_access)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows_cap,), jnp.int32),
            jax.ShapeDtypeStruct((rows_cap,), jnp.int32),
        ],
        interpret=interpret,
    )(rows, count, a_rpt, a_col, b_rpt, b_col)


# ---------------------------------------------------------------------------
# Numeric kernel: accumulate values per row into (col, val) hash tables.
# ---------------------------------------------------------------------------

def _make_numeric_kernel(t_size: int, single_access: bool, val_dtype):
    t_rows, t_lanes = _table_geom(t_size)
    guard = _PROBE_GUARD_FACTOR * t_size

    def kernel(rows_smem, count_smem, a_rpt, a_col, a_val, b_rpt, b_col,
               b_val, col_out, val_out, acc_out, col_tab, val_tab):
        i = pl.program_id(0)
        active = i < count_smem[0]
        r = rows_smem[i]
        col_tab[...] = jnp.full((t_rows, t_lanes), -1, jnp.int32)
        val_tab[...] = jnp.zeros((t_rows, t_lanes), val_dtype)
        a_lo = jnp.where(active, a_rpt[r], 0)
        a_hi = jnp.where(active, a_rpt[r + 1], 0)

        def insert(key, prod, acc):
            h0 = _hash_init(key, t_size)

            def cond(st):
                h, done, probes = st
                return (~done) & (probes < guard)

            if single_access:
                # Alg 5: one col-table transaction per iteration; the value
                # slot is touched only on the terminal iteration.
                def body(st):
                    h, done, probes = st
                    hr, hl = h // 128, h % 128
                    cur = col_tab[hr, hl]                     # 1 transaction
                    empty = cur == -1
                    hit = empty | (cur == key)
                    col_tab[hr, hl] = jnp.where(empty, key, cur)
                    val_tab[hr, hl] = val_tab[hr, hl] + jnp.where(
                        hit, prod, jnp.zeros((), val_dtype))
                    return (_hash_next(h, t_size), hit, probes + 1)
            else:
                # nsparse-style: read, branch, then CAS-claim (second
                # transaction) when the slot was empty.
                def body(st):
                    h, done, probes = st
                    hr, hl = h // 128, h % 128
                    cur = col_tab[hr, hl]                     # transaction 1
                    empty = cur == -1
                    cur2 = jnp.where(empty, col_tab[hr, hl], cur)  # transaction 2
                    col_tab[hr, hl] = jnp.where(empty, key, cur2)
                    hit = empty | (cur == key)
                    val_tab[hr, hl] = val_tab[hr, hl] + jnp.where(
                        hit, prod, jnp.zeros((), val_dtype))
                    return (_hash_next(h, t_size), hit,
                            probes + jnp.where(empty, 2, 1).astype(jnp.int32))

            h, done, probes = jax.lax.while_loop(
                cond, body, (h0, jnp.asarray(False), jnp.int32(0)))
            return acc + probes

        def outer(e, acc):
            k = a_col[a_lo + e]
            av = a_val[a_lo + e]
            b_lo = b_rpt[k]
            b_hi = b_rpt[k + 1]

            def inner(j, acc):
                c = b_col[b_lo + j]
                bv = b_val[b_lo + j]
                return insert(c, av * bv, acc)

            return jax.lax.fori_loop(0, b_hi - b_lo, inner, acc)

        acc = jax.lax.fori_loop(0, a_hi - a_lo, outer, jnp.int32(0))
        col_out[0, :] = col_tab[...].reshape(-1)
        val_out[0, :] = val_tab[...].reshape(-1)
        acc_out[0] = jnp.where(active, acc, 0)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("t_size", "rows_cap", "single_access", "interpret"))
def numeric_bin_call(rows, count, a_rpt, a_col, a_val, b_rpt, b_col, b_val,
                     *, t_size: int, rows_cap: int, single_access: bool,
                     interpret: Optional[bool] = None):
    """Run the numeric hash kernel over one bin.

    Returns (col_tabs, val_tabs, accesses):
      col_tabs (rows_cap, t_pad) int32 — raw hash tables (-1 = empty);
      val_tabs (rows_cap, t_pad);  accesses (rows_cap,) int32.
    """
    interpret = resolve_interpret(interpret)
    t_rows, t_lanes = _table_geom(t_size)
    t_pad = t_rows * t_lanes
    val_dtype = a_val.dtype
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(rows_cap,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 6,
        out_specs=[
            pl.BlockSpec((1, t_pad), lambda i, rows, cnt: (i, 0)),
            pl.BlockSpec((1, t_pad), lambda i, rows, cnt: (i, 0)),
            pl.BlockSpec((1,), lambda i, rows, cnt: (i,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((t_rows, t_lanes), jnp.int32),
            pltpu.VMEM((t_rows, t_lanes), val_dtype),
        ],
    )
    kernel = _make_numeric_kernel(t_size, single_access, val_dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows_cap, t_pad), jnp.int32),
            jax.ShapeDtypeStruct((rows_cap, t_pad), val_dtype),
            jax.ShapeDtypeStruct((rows_cap,), jnp.int32),
        ],
        interpret=interpret,
    )(rows, count, a_rpt, a_col, a_val, b_rpt, b_col, b_val)


# ---------------------------------------------------------------------------
# Fused symbolic->numeric kernel: ONE table build per row emits nnz AND the
# accumulated (col, val) table — with optional multi-row VMEM packing.
# ---------------------------------------------------------------------------

def _packed_geom(t_size: int, pack: int) -> Tuple[int, int]:
    """Packed VMEM scratch geometry.

    ``pack`` sub-tables of ``t_size`` entries live at stride ``stride``
    inside one lane-aligned (t_rows, 128) tile; returns (t_rows, stride).
    ``pack`` must be a power of two <= 128 so the tile splits evenly.
    """
    assert pack >= 1 and pack & (pack - 1) == 0 and pack <= 128, pack
    t_rows = max(1, -(-(pack * t_size) // 128))
    flat = t_rows * 128
    assert flat % pack == 0, (t_size, pack)
    return t_rows, flat // pack


def _make_fused_kernel(t_size: int, pack: int, single_access: bool,
                       val_dtype):
    t_rows, stride = _packed_geom(t_size, pack)
    guard = _PROBE_GUARD_FACTOR * t_size

    def kernel(rows_smem, count_smem, a_rpt, a_col, a_val, b_rpt, b_col,
               b_val, nnz_out, col_out, val_out, acc_out, col_tab, val_tab):
        i = pl.program_id(0)
        # One fresh tile per grid step; sub-row j owns the slice
        # [j*stride, j*stride + t_size) of the flattened tile.
        col_tab[...] = jnp.full((t_rows, 128), -1, jnp.int32)
        val_tab[...] = jnp.zeros((t_rows, 128), val_dtype)

        for j in range(pack):           # static unroll over the sub-tables
            idx = i * pack + j
            active = idx < count_smem[0]
            r = rows_smem[idx]
            base = j * stride
            a_lo = jnp.where(active, a_rpt[r], 0)
            a_hi = jnp.where(active, a_rpt[r + 1], 0)

            def insert(key, prod, carry, base=base):
                nnz, acc = carry
                h0 = _hash_init(key, t_size)

                def cond(st):
                    h, done, ins, probes = st
                    return (~done) & (probes < guard)

                if single_access:
                    # Alg 4/5 discipline: ONE col-table transaction per
                    # probe iteration; value touched on the terminal one.
                    def body(st):
                        h, done, ins, probes = st
                        slot = base + h
                        hr, hl = slot // 128, slot % 128
                        cur = col_tab[hr, hl]                 # 1 transaction
                        empty = cur == -1
                        hit = empty | (cur == key)
                        col_tab[hr, hl] = jnp.where(empty, key, cur)
                        val_tab[hr, hl] = val_tab[hr, hl] + jnp.where(
                            hit, prod, jnp.zeros((), val_dtype))
                        return (_hash_next(h, t_size), hit, ins | empty,
                                probes + 1)
                else:
                    # nsparse-style check-then-CAS baseline.
                    def body(st):
                        h, done, ins, probes = st
                        slot = base + h
                        hr, hl = slot // 128, slot % 128
                        cur = col_tab[hr, hl]                 # transaction 1
                        empty = cur == -1
                        cur2 = jnp.where(empty, col_tab[hr, hl], cur)  # 2
                        col_tab[hr, hl] = jnp.where(empty, key, cur2)
                        hit = empty | (cur == key)
                        val_tab[hr, hl] = val_tab[hr, hl] + jnp.where(
                            hit, prod, jnp.zeros((), val_dtype))
                        return (_hash_next(h, t_size), hit, ins | empty,
                                probes +
                                jnp.where(empty, 2, 1).astype(jnp.int32))

                h, done, ins, probes = jax.lax.while_loop(
                    cond, body, (h0, jnp.asarray(False), jnp.asarray(False),
                                 jnp.int32(0)))
                return nnz + ins.astype(jnp.int32), acc + probes

            def outer(e, carry):
                k = a_col[a_lo + e]
                av = a_val[a_lo + e]
                b_lo = b_rpt[k]
                b_hi = b_rpt[k + 1]

                def inner(jj, carry):
                    c = b_col[b_lo + jj]
                    bv = b_val[b_lo + jj]
                    return insert(c, av * bv, carry)

                return jax.lax.fori_loop(0, b_hi - b_lo, inner, carry)

            nnz, acc = jax.lax.fori_loop(0, a_hi - a_lo, outer,
                                         (jnp.int32(0), jnp.int32(0)))
            nnz_out[j] = jnp.where(active, nnz, 0)
            acc_out[j] = jnp.where(active, acc, 0)

        col_out[...] = col_tab[...].reshape(pack, stride)
        val_out[...] = val_tab[...].reshape(pack, stride)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("t_size", "rows_cap", "pack", "single_access",
                     "interpret"))
def fused_bin_call(rows, count, a_rpt, a_col, a_val, b_rpt, b_col, b_val,
                   *, t_size: int, rows_cap: int, pack: int = 1,
                   single_access: bool = True, interpret: Optional[bool] = None):
    """Run the fused symbolic->numeric hash kernel over one bin.

    One grid step builds ``pack`` rows' tables as sub-tables of one VMEM
    tile (``pack=1`` reproduces the one-row-per-step layout).  Returns
    ``(nnz, col_tabs, val_tabs, accesses)``:
      nnz      (rows_cap,) int32 — distinct columns per row;
      col_tabs (rows_cap, stride) int32 — raw per-row tables (-1 empty);
      val_tabs (rows_cap, stride) — accumulated values;
      accesses (rows_cap,) int32 — per-row table transactions.
    """
    interpret = resolve_interpret(interpret)
    assert rows_cap % pack == 0, (rows_cap, pack)
    t_rows, stride = _packed_geom(t_size, pack)
    val_dtype = a_val.dtype
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(rows_cap // pack,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 6,
        out_specs=[
            pl.BlockSpec((pack,), lambda i, rows, cnt: (i,)),
            pl.BlockSpec((pack, stride), lambda i, rows, cnt: (i, 0)),
            pl.BlockSpec((pack, stride), lambda i, rows, cnt: (i, 0)),
            pl.BlockSpec((pack,), lambda i, rows, cnt: (i,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((t_rows, 128), jnp.int32),
            pltpu.VMEM((t_rows, 128), val_dtype),
        ],
    )
    kernel = _make_fused_kernel(t_size, pack, single_access, val_dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows_cap,), jnp.int32),
            jax.ShapeDtypeStruct((rows_cap, stride), jnp.int32),
            jax.ShapeDtypeStruct((rows_cap, stride), val_dtype),
            jax.ShapeDtypeStruct((rows_cap,), jnp.int32),
        ],
        interpret=interpret,
    )(rows, count, a_rpt, a_col, a_val, b_rpt, b_col, b_val)


# ---------------------------------------------------------------------------
# Vectorized epilogue: condense + sort the dumped tables into CSR storage.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("nnz_capacity",))
def numeric_epilogue(col_tabs, val_tabs, bin_rows, count, rpt, c_col, c_val,
                     *, nnz_capacity: int):
    """Sort each row's table by column id and scatter into C storage.

    The paper's condense (shared_offset atomics) + sort phases, vectorized:
    argsort over the table (empties keyed to INT32_MAX sort last), masked
    scatter to ``C.col/C.val`` at ``rpt[row] + j``.
    """
    rows_cap, t_pad = col_tabs.shape
    sort_key = jnp.where(col_tabs < 0, INT32_MAX, col_tabs)
    order = jnp.argsort(sort_key, axis=1)
    col_sorted = jnp.take_along_axis(col_tabs, order, axis=1)
    val_sorted = jnp.take_along_axis(val_tabs, order, axis=1)
    nnz_row = jnp.sum((col_tabs >= 0).astype(jnp.int32), axis=1)

    valid_row = jnp.arange(rows_cap, dtype=jnp.int32) < count
    lane = jnp.arange(t_pad, dtype=jnp.int32)[None, :]
    in_row = lane < nnz_row[:, None]
    mask = in_row & valid_row[:, None]
    start = rpt[jnp.where(valid_row, bin_rows, 0)][:, None]
    target = jnp.where(mask, start + lane, nnz_capacity)   # OOB -> dropped
    c_col = c_col.at[target.reshape(-1)].set(
        col_sorted.reshape(-1), mode="drop")
    c_val = c_val.at[target.reshape(-1)].set(
        val_sorted.reshape(-1), mode="drop")
    return c_col, c_val


# ---------------------------------------------------------------------------
# Schedule-driven drivers (called by the engine and by the binned wrappers).
#
# The launch schedule — which rungs run, with how many (padded) rows each —
# used to be a per-call host decision (``np.asarray(binning.bin_size)``).
# It is now a STATIC argument: ``row_buckets`` gives a pow-2 row-count
# capacity per rung (last entry = the ESC fallback rung), 0 meaning the
# rung is statically absent.  With the schedule static the whole phase is
# one traceable function with zero host syncs; callers verify afterwards
# that the actual bin sizes fit the buckets (the engine folds that check
# into its single finalize sync and grows the plan on overflow).
# ---------------------------------------------------------------------------

def _fallback_rows(binning: Binning, ladder: BinLadder, cap: int, m: int):
    """Fallback-rung row ids padded to static ``cap`` (+ validity mask)."""
    fallback_bin = len(ladder.table_sizes)
    rows, count = binning.rows_of_bin(fallback_bin, cap)
    valid = jnp.arange(cap, dtype=jnp.int32) < count
    return jnp.where(valid, rows, m), valid


def _check_schedule(row_buckets, ladder: BinLadder, fallback_prod_capacity):
    assert len(row_buckets) == ladder.num_bins, (row_buckets, ladder)
    assert not row_buckets[-1] or fallback_prod_capacity > 0, \
        "active fallback rung needs a sub-product capacity"


def symbolic_scheduled(A: CSR, B: CSR, binning: Binning, ladder: BinLadder,
                       *, row_buckets, fallback_prod_capacity: int = 0,
                       single_access: bool = True, interpret: Optional[bool] = None,
                       row_packing: bool = False,
                       collect_accesses: bool = False):
    """Symbolic phase over a static bucketed schedule — fully traceable.

    Rungs are dispatched LARGEST first (the §5.5 launch-order rule: the
    long pole starts earliest), beginning with the ESC fallback rung.
    Returns ``(nnz_buf, sub_prod, accesses)`` where ``sub_prod`` is the
    fallback rung's intermediate-product total (a device scalar the
    caller verifies against ``fallback_prod_capacity``; an overflowed
    fallback truncates its expansion, so results are only trustworthy
    when the check passes).

    ``row_packing`` batches ``ladder.rows_per_block[b]`` rows per grid
    step on rungs whose tables underfill a VMEM tile (``row_buckets``
    must then be multiples of the pack — ``host_schedule(packs=...)``
    guarantees it), exactly as in :func:`fused_scheduled`.
    """
    _check_schedule(row_buckets, ladder, fallback_prod_capacity)
    m = A.nrows
    nnz_buf = jnp.zeros(m + 1, dtype=jnp.int32)
    accesses = jnp.int32(0)
    sub_prod = jnp.int32(0)

    if row_buckets[-1]:
        # Global-memory-analog rung: ESC on the gathered sub-matrix.
        rows, valid = _fallback_rows(binning, ladder, row_buckets[-1], m)
        sub = gather_rows(A, rows, valid)
        sub_prod = jnp.sum(
            jnp.where(valid, nprod_of_rows(A, B, rows), 0)).astype(jnp.int32)
        sub_nnz = esc.symbolic(sub, B, prod_capacity=fallback_prod_capacity)
        tgt = jnp.where(valid, rows, m + 1)
        nnz_buf = nnz_buf.at[tgt].set(sub_nnz[:rows.shape[0]], mode="drop")

    for b in range(len(ladder.table_sizes) - 1, -1, -1):
        rows_cap = row_buckets[b]
        if not rows_cap:
            continue
        pack = ladder.rows_per_block[b] if row_packing else 1
        pack = min(pack, rows_cap)         # both pow-2: stays divisible
        rows, count = binning.rows_of_bin(b, rows_cap)
        nnz_bin, acc_bin = symbolic_bin_call(
            rows, count.reshape(1), A.rpt, A.col, B.rpt, B.col,
            t_size=ladder.table_sizes[b], rows_cap=rows_cap, pack=pack,
            single_access=single_access, interpret=interpret)
        valid = jnp.arange(rows_cap, dtype=jnp.int32) < count
        tgt = jnp.where(valid, rows, m + 1)
        nnz_buf = nnz_buf.at[tgt].set(nnz_bin, mode="drop")
        if collect_accesses:
            accesses = accesses + jnp.sum(jnp.where(valid, acc_bin, 0))

    return nnz_buf, sub_prod, accesses


def schedule_bucket(count: int, *, m_cap: int, headroom: float,
                    pack: int = 1) -> int:
    """Pow-2 bin-count bucket for one rung's observed row count.

    The ONE shared copy of the schedule bucket math: ``host_schedule``
    (cold derivation) and ``engine/autotune`` (trim re-derivation from
    observed maxima) must agree bit-for-bit or a trimmed schedule would
    drift from what a later cold floor re-derives.  ``count`` is coerced
    to a Python int, so near-2^31 counts widen instead of wrapping.

    With headroom the bucket must strictly EXCEED the headroom target: an
    observed count already on a pow-2 would otherwise learn a bucket with
    zero margin, and any jitter overflows it (the boundary-straddle
    failure the headroom exists to prevent).  headroom=1.0 (the faithful
    per-call path) keeps exact buckets.  ``pack`` floors the bucket at a
    rung's pow-2 rows-per-block so packed kernels get whole grid steps.
    """
    count = int(count)
    if not count:
        return 0
    lo = max(_ROW_BUCKET_MIN, int(pack))
    strict = 1 if headroom > 1.0 else 0
    return min(max(m_cap, lo),
               next_bucket(int(np.ceil(count * headroom)) + strict,
                           minimum=lo))


def fallback_capacity_bucket(sub_prod: int, *, headroom: float) -> int:
    """Pow-2 capacity bucket for the fallback rung's ESC expansion (same
    strict-exceed rule as :func:`schedule_bucket`; host int math)."""
    strict = 1 if headroom > 1.0 else 0
    return next_bucket(int(np.ceil(max(int(sub_prod), 1) * headroom))
                       + strict, minimum=_ROW_BUCKET_MIN)


def host_schedule(A: CSR, B: CSR, binning: Binning, ladder: BinLadder, *,
                  headroom: float = 1.0, packs: Tuple[int, ...] = None):
    """Host-side schedule derivation (the cold path's ONE metadata sync).

    Reads the device bin sizes, buckets each rung's row count to a pow-2
    capacity (0 = empty rung, statically skipped), and — when the
    fallback rung is populated — syncs its sub-product total to size the
    ESC expansion.  ``headroom`` over-provisions the buckets (the engine
    learns schedules with headroom so steady-state bin-count jitter stays
    inside the learned buckets instead of forcing retraces: padding rows
    are masked grid steps, far cheaper than a recompile).

    ``packs`` (per table rung, e.g. ``ladder.rows_per_block``) floors each
    populated rung's bucket at its pow-2 rows-per-block so packed kernels
    always get a whole number of grid steps; padding rows beyond the bin
    count are masked sub-tables.
    """
    sizes = np.asarray(binning.bin_size)       # host sync: launch schedule
    m_cap = next_bucket(binning.bins.shape[0], minimum=_ROW_BUCKET_MIN)

    row_buckets = tuple(
        schedule_bucket(
            s, m_cap=m_cap, headroom=headroom,
            pack=(packs[b] if packs is not None and b < len(packs) else 1))
        for b, s in enumerate(sizes))
    fallback_prod_capacity = 0
    if row_buckets[-1]:
        rows, valid = _fallback_rows(binning, ladder, row_buckets[-1],
                                     A.nrows)
        sub_prod = int(jnp.sum(                # host sync: fallback alloc
            jnp.where(valid, nprod_of_rows(A, B, rows), 0)))
        fallback_prod_capacity = fallback_capacity_bucket(
            sub_prod, headroom=headroom)
    return row_buckets, fallback_prod_capacity


def symbolic_binned(A: CSR, B: CSR, binning: Binning, ladder: BinLadder, *,
                    prod_capacity: int = 0, single_access: bool = True,
                    interpret: Optional[bool] = None,
                    row_packing: bool = False,
                    collect_accesses: bool = False):
    """Host-orchestrated symbolic phase (cold / standalone path).

    Syncs the bin sizes once to derive an exact bucketed schedule, then
    runs the traceable ``symbolic_scheduled`` form.  Returns the (M+1,)
    n_nz buffer (optionally also the total table-access count).
    ``prod_capacity`` is unused (kept for signature compatibility: the
    hash rungs size their tables from the ladder, not the expansion).
    """
    del prod_capacity
    packs = ladder.rows_per_block if row_packing else None
    row_buckets, fall_cap = host_schedule(A, B, binning, ladder, packs=packs)
    nnz_buf, _, accesses = symbolic_scheduled(
        A, B, binning, ladder, row_buckets=row_buckets,
        fallback_prod_capacity=fall_cap, single_access=single_access,
        interpret=interpret, row_packing=row_packing,
        collect_accesses=collect_accesses)
    if collect_accesses:
        return nnz_buf, accesses
    return nnz_buf


def nprod_of_rows(A: CSR, B: CSR, rows: jax.Array) -> jax.Array:
    b_sizes = B.nnz_per_row()
    safe_rows = jnp.minimum(rows, A.nrows - 1)
    lo, hi = A.rpt[safe_rows], A.rpt[safe_rows + 1]

    def per_row(l, h):
        # Sum of B-row sizes over a variable slice — segment via mask.
        idx = jnp.arange(A.capacity, dtype=jnp.int32)
        mask = (idx >= l) & (idx < h)
        return jnp.sum(jnp.where(mask, b_sizes[jnp.minimum(A.col, B.nrows - 1)], 0))

    return jax.vmap(per_row)(lo, hi)


def numeric_scheduled(A: CSR, B: CSR, rpt: jax.Array, binning: Binning,
                      ladder: BinLadder, *, row_buckets,
                      nnz_capacity: int, fallback_prod_capacity: int = 0,
                      single_access: bool = True, interpret: Optional[bool] = None,
                      collect_accesses: bool = False):
    """Numeric phase over a static bucketed schedule — fully traceable.

    Mirrors ``symbolic_scheduled``: per-rung fixed-capacity kernels,
    largest rung (the ESC fallback) first, no host syncs.  Returns
    ``(C, sub_prod, accesses)``; the caller verifies ``sub_prod`` against
    ``fallback_prod_capacity`` (overflow truncates the fallback rows).
    """
    _check_schedule(row_buckets, ladder, fallback_prod_capacity)
    m, n = A.nrows, B.ncols
    c_col = jnp.zeros(nnz_capacity, jnp.int32)
    c_val = jnp.zeros(nnz_capacity, A.val.dtype)
    accesses = jnp.int32(0)
    sub_prod = jnp.int32(0)

    if row_buckets[-1]:
        rows, valid = _fallback_rows(binning, ladder, row_buckets[-1], m)
        sub = gather_rows(A, rows, valid)
        sub_prod = jnp.sum(
            jnp.where(valid, nprod_of_rows(A, B, rows), 0)).astype(jnp.int32)
        subC = esc.spgemm_fused(sub, B,
                                prod_capacity=fallback_prod_capacity,
                                nnz_capacity=fallback_prod_capacity)
        c_col, c_val = scatter_sub_rows(
            subC, rows, valid, rpt, c_col, c_val, nnz_capacity=nnz_capacity)

    for b in range(len(ladder.table_sizes) - 1, -1, -1):
        rows_cap = row_buckets[b]
        if not rows_cap:
            continue
        rows, count = binning.rows_of_bin(b, rows_cap)
        col_tabs, val_tabs, acc_bin = numeric_bin_call(
            rows, count.reshape(1), A.rpt, A.col, A.val, B.rpt, B.col, B.val,
            t_size=ladder.table_sizes[b], rows_cap=rows_cap,
            single_access=single_access, interpret=interpret)
        c_col, c_val = numeric_epilogue(
            col_tabs, val_tabs, rows, count, rpt, c_col, c_val,
            nnz_capacity=nnz_capacity)
        if collect_accesses:
            valid = jnp.arange(rows_cap, dtype=jnp.int32) < count
            accesses = accesses + jnp.sum(jnp.where(valid, acc_bin, 0))

    C = CSR(rpt=rpt, col=c_col, val=c_val, shape=(m, n))
    return C, sub_prod, accesses


def numeric_binned(A: CSR, B: CSR, rpt: jax.Array, binning: Binning,
                   ladder: BinLadder, *, prod_capacity: int = 0,
                   nnz_capacity: int, single_access: bool = True,
                   interpret: Optional[bool] = None,
                   collect_accesses: bool = False):
    """Host-orchestrated numeric phase (cold / standalone path) -> CSR.

    Schedule derivation as in ``symbolic_binned``; ``prod_capacity`` is
    unused (signature compatibility).
    """
    del prod_capacity
    row_buckets, fall_cap = host_schedule(A, B, binning, ladder)
    C, _, accesses = numeric_scheduled(
        A, B, rpt, binning, ladder, row_buckets=row_buckets,
        nnz_capacity=nnz_capacity, fallback_prod_capacity=fall_cap,
        single_access=single_access, interpret=interpret,
        collect_accesses=collect_accesses)
    if collect_accesses:
        return C, accesses
    return C


def fused_scheduled(A: CSR, B: CSR, binning: Binning, ladder: BinLadder, *,
                    row_buckets, nnz_capacity: int,
                    fallback_prod_capacity: int = 0,
                    single_access: bool = True, interpret: Optional[bool] = None,
                    row_packing: bool = False,
                    collect_accesses: bool = False):
    """Fused symbolic->numeric phase over a static schedule — traceable.

    ONE binning (by n_prod, the symbolic ladder — the only pre-data row
    size), ONE table build per row: each populated rung's
    :func:`fused_bin_call` emits per-row nnz AND the accumulated (col,
    val) tables, the fallback rung runs the single-expansion ESC
    (``esc.spgemm_fused``, its n_nz read off the sub-result's rpt), and
    once every row's nnz is known the row pointers are an exclusive sum
    and the dumped tables condense/sort/scatter into C — no second probe
    pass anywhere.  The symbolic-ladder tables are sized by n_prod
    (>= n_nz), so the numeric accumulation can never overflow them; the
    larger tables trade VMEM footprint for a LOWER collision rate than
    the two-pass numeric rungs (§5.6's trade-off, resolved towards fewer
    transactions).

    ``row_packing`` batches ``ladder.rows_per_block[b]`` rows per grid
    step on rungs whose tables underfill a VMEM tile (``row_buckets``
    must then be multiples of the pack — ``host_schedule(packs=...)``
    guarantees it).

    Returns ``(C, nnz, sub_prod, accesses)``: the assembled CSR, the (M,)
    per-row nnz (the caller's total_nnz source), the fallback rung's
    sub-product total to verify against ``fallback_prod_capacity``, and
    the summed table-transaction count (0 unless ``collect_accesses``).
    """
    _check_schedule(row_buckets, ladder, fallback_prod_capacity)
    m, n = A.nrows, B.ncols
    nnz_buf = jnp.zeros(m + 1, dtype=jnp.int32)
    accesses = jnp.int32(0)
    sub_prod = jnp.int32(0)
    fallback = None
    kept = []

    if row_buckets[-1]:
        # Global-memory-analog rung, fused form: one ESC expansion yields
        # both the sub-result values AND (via its rpt) the per-row nnz.
        rows, valid = _fallback_rows(binning, ladder, row_buckets[-1], m)
        sub = gather_rows(A, rows, valid)
        sub_prod = jnp.sum(
            jnp.where(valid, nprod_of_rows(A, B, rows), 0)).astype(jnp.int32)
        subC = esc.spgemm_fused(sub, B,
                                prod_capacity=fallback_prod_capacity,
                                nnz_capacity=fallback_prod_capacity)
        cap = rows.shape[0]
        sub_nnz = (subC.rpt[1:cap + 1] - subC.rpt[:cap]).astype(jnp.int32)
        tgt = jnp.where(valid, rows, m + 1)
        nnz_buf = nnz_buf.at[tgt].set(sub_nnz, mode="drop")
        fallback = (subC, rows, valid)

    for b in range(len(ladder.table_sizes) - 1, -1, -1):
        rows_cap = row_buckets[b]
        if not rows_cap:
            continue
        pack = ladder.rows_per_block[b] if row_packing else 1
        pack = min(pack, rows_cap)         # both pow-2: stays divisible
        rows, count = binning.rows_of_bin(b, rows_cap)
        nnz_bin, col_tabs, val_tabs, acc_bin = fused_bin_call(
            rows, count.reshape(1), A.rpt, A.col, A.val, B.rpt, B.col, B.val,
            t_size=ladder.table_sizes[b], rows_cap=rows_cap, pack=pack,
            single_access=single_access, interpret=interpret)
        valid = jnp.arange(rows_cap, dtype=jnp.int32) < count
        tgt = jnp.where(valid, rows, m + 1)
        nnz_buf = nnz_buf.at[tgt].set(nnz_bin, mode="drop")
        if collect_accesses:
            accesses = accesses + jnp.sum(jnp.where(valid, acc_bin, 0))
        kept.append((rows, count, col_tabs, val_tabs))

    nnz = nnz_buf[:m]
    rpt = exclusive_sum_in_place(nnz_buf)
    c_col = jnp.zeros(nnz_capacity, jnp.int32)
    c_val = jnp.zeros(nnz_capacity, A.val.dtype)
    if fallback is not None:
        subC, rows, valid = fallback
        c_col, c_val = scatter_sub_rows(
            subC, rows, valid, rpt, c_col, c_val, nnz_capacity=nnz_capacity)
    for rows, count, col_tabs, val_tabs in kept:
        c_col, c_val = numeric_epilogue(
            col_tabs, val_tabs, rows, count, rpt, c_col, c_val,
            nnz_capacity=nnz_capacity)

    C = CSR(rpt=rpt, col=c_col, val=c_val, shape=(m, n))
    return C, nnz, sub_prod, accesses


def fused_binned(A: CSR, B: CSR, binning: Binning, ladder: BinLadder, *,
                 nnz_capacity: int, single_access: bool = True,
                 interpret: Optional[bool] = None, row_packing: bool = False,
                 collect_accesses: bool = False):
    """Host-orchestrated fused pipeline (cold / standalone path) -> CSR.

    ``binning`` must be the n_prod binning on the SYMBOLIC ladder (the
    fused kernel sizes each row's one table by n_prod).  Schedule
    derivation as in ``symbolic_binned``, with pack-aligned buckets when
    ``row_packing``.
    """
    packs = ladder.rows_per_block if row_packing else None
    row_buckets, fall_cap = host_schedule(A, B, binning, ladder, packs=packs)
    C, nnz, _, accesses = fused_scheduled(
        A, B, binning, ladder, row_buckets=row_buckets,
        nnz_capacity=nnz_capacity, fallback_prod_capacity=fall_cap,
        single_access=single_access, interpret=interpret,
        row_packing=row_packing, collect_accesses=collect_accesses)
    if collect_accesses:
        return C, accesses
    return C


@functools.partial(jax.jit, static_argnames=("nnz_capacity",))
def scatter_sub_rows(subC: CSR, orig_rows, valid, rpt, c_col, c_val, *,
                     nnz_capacity: int):
    """Copy rows of a sub-CSR result into the final C storage."""
    sub_rows = subC.row_ids()                     # sub-row of each entry
    entry_ok = subC.entry_mask() & (sub_rows < subC.nrows)
    safe_sub = jnp.minimum(sub_rows, subC.nrows - 1)
    row_ok = entry_ok & valid[safe_sub]
    orig = orig_rows[safe_sub]
    offs = jnp.arange(subC.capacity, dtype=jnp.int32) - subC.rpt[safe_sub]
    target = jnp.where(row_ok, rpt[jnp.minimum(orig, rpt.shape[0] - 2)] + offs,
                       nnz_capacity)
    c_col = c_col.at[target].set(subC.col, mode="drop")
    c_val = c_val.at[target].set(subC.val, mode="drop")
    return c_col, c_val
