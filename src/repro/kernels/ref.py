"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's sweep test asserts allclose against these references.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import CSR


def spgemm_dense_ref(A: CSR, B: CSR) -> jax.Array:
    """Dense oracle for any SpGEMM path."""
    return A.to_dense() @ B.to_dense()


def symbolic_ref(A: CSR, B: CSR) -> np.ndarray:
    """n_nz per output row, from the dense product's support."""
    d = np.asarray(spgemm_dense_ref(A, B))
    return (d != 0).sum(axis=1).astype(np.int32)


def row_nnz_from_support(A: CSR, B: CSR) -> np.ndarray:
    """Structural n_nz per row (counts symbolic support even where values
    cancel numerically — matches what hash/ESC symbolic computes)."""
    a = np.asarray(A.to_dense()) != 0
    b = np.asarray(B.to_dense()) != 0
    support = (a.astype(np.int64) @ b.astype(np.int64)) > 0
    return support.sum(axis=1).astype(np.int32)


def bsr_spmm_ref(block_rows, block_cols, blocks, dense, *, nrows_blocks,
                 block_shape):
    """Block-CSR (COO-listed blocks) × dense reference."""
    bm, bk = block_shape
    out = jnp.zeros((nrows_blocks * bm, dense.shape[1]), dense.dtype)
    for i in range(block_rows.shape[0]):
        r, c = int(block_rows[i]), int(block_cols[i])
        if r < 0:
            continue
        out = out.at[r * bm:(r + 1) * bm].add(
            blocks[i] @ dense[c * bk:(c + 1) * bk])
    return out


def attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """Unfused attention oracle.  q,k,v: (B, S, H, D) / k,v may have fewer
    KV heads (GQA) — heads are repeated to match."""
    bq, sq, hq, d = q.shape
    hk = k.shape[2]
    if hk != hq:
        rep = hq // hk
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    if causal:
        sk = k.shape[1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def ssm_scan_ref(x, dt, A_diag, Bmat, Cmat, D):
    """Selective-SSM (Mamba-style) sequential oracle.

    x: (B, L, H) inputs; dt: (B, L, H) softplus-ed step; A_diag: (H, N);
    Bmat/Cmat: (B, L, N); D: (H,).  Returns (B, L, H).
    """
    bsz, L, H = x.shape
    N = A_diag.shape[1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        dA = jnp.exp(dtt[..., None] * A_diag[None])          # (B, H, N)
        dBx = dtt[..., None] * bt[:, None, :] * xt[..., None]
        h = h * dA + dBx
        y = jnp.einsum("bhn,bn->bh", h, ct)
        return h, y

    h0 = jnp.zeros((bsz, H, N), x.dtype)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bmat, 1, 0), jnp.moveaxis(Cmat, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)
    return y + x * D[None, None]
