"""Pallas TPU kernel: block-CSR sparse × dense matmul (BCSR SpMM).

The paper's machinery lifted to TPU-native BLOCK granularity (DESIGN.md
§2): sparsity is expressed over (bm × bk) tiles so the per-tile work is a
dense MXU matmul, while the block row-pointer/column-id metadata keeps the
paper's CSR discipline.  Used as the building block for block-sparse
attention masks and sparse-weight layers; also the "numeric phase" of a
block-level SpGEMM where the output topology came from a (block) symbolic
phase.

Layout: blocks are COO-listed per block-row in CSR order:
  blk_rows (nnzb,) int32, blk_cols (nnzb,) int32, blocks (nnzb, bm, bk).
Grid = (nnzb,): each step multiplies one sparse tile into its output row
stripe — accumulation across steps with the same output block index is
race-free on TPU (sequential grid).  Rows ids arrive via scalar prefetch
so the output index_map can place each step's stripe.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret


@functools.partial(jax.jit, static_argnames=("n_block_rows", "interpret"))
def bsr_spmm(blk_rows, blk_cols, blocks, dense, *, n_block_rows: int,
             interpret: Optional[bool] = None):
    """(BCSR blocks) @ dense.

    blk_rows/blk_cols: (nnzb,) int32 sorted by row (CSR block order);
    blocks: (nnzb, bm, bk); dense: (K, N) with K = n_block_cols * bk.
    Returns (n_block_rows * bm, N).  Padding blocks: row id = a repeat of
    the last row with a zero block (contributes nothing).
    ``interpret=None`` auto-detects (compiled on TPU, interpreted elsewhere).
    """
    interpret = resolve_interpret(interpret)
    nnzb, bm, bk = blocks.shape
    n = dense.shape[1]
    dense_b = dense.reshape(-1, bk, n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,         # blk_rows, blk_cols
        grid=(nnzb,),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda i, rows, cols: (i, 0, 0)),
            pl.BlockSpec((1, bk, n), lambda i, rows, cols: (cols[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, n), lambda i, rows, cols:
                               (rows[i], 0, 0)),
        scratch_shapes=[pltpu.VMEM((bm, n), jnp.float32)],
    )

    def kernel(rows_smem, cols_smem, blocks_ref, dense_ref, out_ref,
               acc_ref):
        i = pl.program_id(0)
        r = rows_smem[i]
        prev_r = rows_smem[jnp.maximum(i - 1, 0)]
        new_stripe = (i == 0) | (r != prev_r)

        @pl.when(new_stripe)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(blocks_ref[0], dense_ref[0],
                                preferred_element_type=jnp.float32)
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows, bm, n), dense.dtype),
        interpret=interpret,
    )(blk_rows, blk_cols, blocks, dense_b)
    return out.reshape(n_block_rows * bm, n)
