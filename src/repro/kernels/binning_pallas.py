"""Pallas TPU kernel: binning pass-1 histogram with VMEM accumulation.

The direct analog of the paper's Alg. 1: each grid step (thread-block
analog) owns a block of rows, classifies them against the rung bounds in
registers/VMEM, accumulates a LOCAL histogram, and adds one line into the
global bin_size output — one HBM transaction per block instead of one
atomic per row (the paper's s_bin_size -> d_bin_size staging).  Also
tracks the running max row size (Alg. 1 line 6/19) for the Alg. 3
fast-path decision.

Grid steps on TPU run sequentially per core, so the accumulation into the
shared output block is race-free by construction (the same property the
paper gets from atomics).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret


def _make_kernel(upper: Tuple[int, ...], num_bins: int, block: int,
                 m: int):
    def kernel(sizes_ref, hist_ref, max_ref):
        i = pl.program_id(0)
        vals = sizes_ref[...]                          # (block,)
        idx = i * block + jax.lax.iota(jnp.int32, block)
        valid = idx < m
        # classify: first rung admitting the size == count of exceeded
        # bounds (vectorized Alg-1 range scan; bounds are static ints)
        bin_ids = jnp.zeros((block,), jnp.int32)
        for bound in upper:
            bin_ids += (vals > bound).astype(jnp.int32)

        @pl.when(i == 0)
        def _init():
            hist_ref[...] = jnp.zeros_like(hist_ref)
            max_ref[...] = jnp.zeros_like(max_ref)

        # local histogram (VMEM) -> one accumulate into the output line
        local = jnp.zeros((num_bins,), jnp.int32)
        for b in range(num_bins):
            local = local.at[b].set(
                jnp.sum(((bin_ids == b) & valid).astype(jnp.int32)))
        hist_ref[0, :num_bins] += local
        max_ref[0, 0] = jnp.maximum(
            max_ref[0, 0], jnp.max(jnp.where(valid, vals, 0)))

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("upper", "num_bins", "block",
                                    "interpret"))
def binning_histogram(sizes, *, upper: Tuple[int, ...], num_bins: int,
                      block: int = 1024, interpret: Optional[bool] = None):
    """Pass-1 of the binning method as a Pallas kernel.

    ``interpret=None`` auto-detects (compiled on TPU, interpreted
    elsewhere).  Returns (bin_size (num_bins,) int32, max_size () int32)."""
    interpret = resolve_interpret(interpret)
    m = sizes.shape[0]
    m_pad = -(-m // block) * block
    if m_pad != m:
        sizes = jnp.pad(sizes, (0, m_pad - m))
    nb_pad = max(num_bins, 8)
    kernel = _make_kernel(upper, num_bins, block, m)
    hist, mx = pl.pallas_call(
        kernel,
        grid=(m_pad // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((1, nb_pad), lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, nb_pad), jnp.int32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )(sizes.astype(jnp.int32))
    return hist[0, :num_bins], mx[0, 0]
