# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared kernel-layer runtime helpers."""
from __future__ import annotations

from typing import Optional

import jax


def default_interpret() -> bool:
    """Whether Pallas kernels should run in interpret mode by default.

    Interpret mode emulates the TPU grid on the host — required in CPU
    containers, pure overhead on real hardware.  Auto-detection keeps one
    code path: compiled on a TPU backend, interpreted everywhere else.
    """
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve an ``interpret`` knob: ``None`` means auto-detect."""
    return default_interpret() if interpret is None else bool(interpret)
