"""Jit'd public wrappers over the Pallas kernels in this package."""
from __future__ import annotations

from . import spgemm_hash
from .spgemm_hash import (host_schedule, numeric_bin_call, numeric_binned,
                          numeric_scheduled, symbolic_bin_call,
                          symbolic_binned, symbolic_scheduled)

__all__ = [
    "spgemm_hash", "symbolic_bin_call", "numeric_bin_call",
    "symbolic_binned", "numeric_binned",
    "symbolic_scheduled", "numeric_scheduled", "host_schedule",
]
