"""TRC — trace-safety rules for the jitted steady state.

* ``TRC001``: a host sync inside a traced function.  ``.item()`` /
  ``.block_until_ready()`` on traced values, ``np.asarray`` /
  ``np.array`` materialization, ``jax.device_get``, and ``int()`` /
  ``float()`` coercion of a traced value all force the accelerator
  pipeline to drain — in the OpSparse steady state (zero-retrace
  scheduled kernels, §5.4 alloc/exec overlap) that is the exact
  stall class the engine exists to remove.
* ``TRC002``: data-dependent Python branching inside a traced
  function (``if``/``while``/ternary on a traced value) — under
  ``jax.jit`` this either fails to trace or silently bakes one branch
  into the executable.  Branching on ``static_argnames`` parameters
  or closure-captured host config is fine and not flagged.
"""

from __future__ import annotations

import ast
from typing import List

from .callgraph import (
    CallGraph,
    analyze_taint,
    function_scope,
    resolve_dotted,
)
from .core import Finding, Project

RULES = {
    "TRC001": "host sync inside a jit-traced function",
    "TRC002": "data-dependent Python branch inside a jit-traced function",
}

_SYNC_ATTRS = {"block_until_ready"}
_NP_MATERIALIZERS = {"asarray", "array"}
_COERCIONS = {"int", "float"}


def run(project: Project, graph: CallGraph) -> List[Finding]:
    findings: List[Finding] = []
    for fn, tainted_params in sorted(
            graph.traced.items(), key=lambda kv: (kv[0].sf.relpath, kv[0].node.lineno)):
        mi = graph.modules[fn.sf.modname]
        scope = function_scope(graph, fn)
        taint = analyze_taint(fn, tainted_params, scope, mi, graph)
        tainted = taint.tainted_names

        def expr_tainted(node: ast.AST) -> bool:
            if isinstance(node, ast.Name):
                return node.id in tainted
            if isinstance(node, ast.Subscript):
                return expr_tainted(node.value)
            if isinstance(node, ast.Call):
                if isinstance(node.func, ast.Attribute):
                    if expr_tainted(node.func.value):
                        return True
                return any(expr_tainted(a) for a in node.args) or \
                    any(expr_tainted(kw.value) for kw in node.keywords)
            if isinstance(node, ast.BinOp):
                return expr_tainted(node.left) or expr_tainted(node.right)
            if isinstance(node, ast.UnaryOp):
                return expr_tainted(node.operand)
            if isinstance(node, ast.BoolOp):
                return any(expr_tainted(v) for v in node.values)
            if isinstance(node, ast.Compare):
                # `x is None` / `x is not None` resolves structurally at
                # trace time (None is never a tracer) — not data-dependent
                if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops) \
                        and all(isinstance(c, ast.Constant) and c.value is None
                                for c in node.comparators):
                    return False
                return expr_tainted(node.left) or \
                    any(expr_tainted(c) for c in node.comparators)
            if isinstance(node, (ast.Tuple, ast.List)):
                return any(expr_tainted(e) for e in node.elts)
            if isinstance(node, ast.IfExp):
                return expr_tainted(node.body) or expr_tainted(node.orelse)
            return False

        where = f"traced function `{fn.qualname}`"
        for node in ast.walk(fn.node):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn.node:
                # nested defs get their own traced entry if jit-wrapped
                continue
            if isinstance(node, ast.Call):
                findings.extend(_check_call(node, fn, mi, where, expr_tainted))
            elif isinstance(node, (ast.If, ast.While)):
                if expr_tainted(node.test):
                    findings.append(Finding(
                        rule="TRC002", path=fn.sf.relpath,
                        line=node.test.lineno, col=node.test.col_offset,
                        message=f"data-dependent Python branch in {where}: "
                                "the condition depends on a traced value",
                        hint="use jnp.where / lax.cond / lax.select, or mark "
                             "the driving argument static (static_argnames) "
                             "if it is host config",
                    ))
            elif isinstance(node, ast.IfExp):
                if expr_tainted(node.test):
                    findings.append(Finding(
                        rule="TRC002", path=fn.sf.relpath,
                        line=node.test.lineno, col=node.test.col_offset,
                        message=f"data-dependent ternary in {where}: the "
                                "condition depends on a traced value",
                        hint="use jnp.where on the traced condition",
                    ))
    return findings


def _check_call(node: ast.Call, fn, mi, where: str, expr_tainted) -> List[Finding]:
    out: List[Finding] = []
    func = node.func
    loc = dict(path=fn.sf.relpath, line=node.lineno, col=node.col_offset)

    if isinstance(func, ast.Attribute):
        if func.attr == "item" and not node.args and expr_tainted(func.value):
            out.append(Finding(
                rule="TRC001", message=f".item() host sync in {where}",
                hint="keep the value on-device (jnp scalar); fetch it once "
                     "outside the jit boundary if the host truly needs it",
                **loc))
        elif func.attr in _SYNC_ATTRS:
            out.append(Finding(
                rule="TRC001",
                message=f".{func.attr}() host sync in {where}",
                hint="synchronize outside the traced region (e.g. at the "
                     "finalize/verify boundary that already host-syncs)",
                **loc))
        else:
            dotted = resolve_dotted(func, mi)
            if dotted in {"jax.device_get"}:
                out.append(Finding(
                    rule="TRC001",
                    message=f"jax.device_get in {where} forces a device->host "
                            "copy under trace",
                    hint="return the array from the jitted function and fetch "
                         "it at the caller",
                    **loc))
            elif dotted is not None and dotted.startswith("numpy.") \
                    and dotted.split(".")[-1] in _NP_MATERIALIZERS:
                out.append(Finding(
                    rule="TRC001",
                    message=f"{dotted.replace('numpy', 'np')}() in {where} "
                            "materializes a traced value on the host",
                    hint="use jnp equivalents inside traced code; np.* belongs "
                         "on the cold/host planning path only",
                    **loc))
    elif isinstance(func, ast.Name) and func.id in _COERCIONS:
        if any(expr_tainted(a) for a in node.args):
            out.append(Finding(
                rule="TRC001",
                message=f"{func.id}() coerces a traced value to host in {where}",
                hint="keep device scalars as 0-d jnp arrays under trace; "
                     "widen/coerce on the host after the jit call returns",
                **loc))
    return out
