"""INT001 — host-int width discipline (automates the PR 5 audit).

Device arrays in this repo are int32 (x64 is off), so anything fetched
to the host — ``jax.device_get(...)``, ``np.asarray(device_val)``, an
explicit ``np.int32(...)`` — carries 32-bit numpy scalars whose
arithmetic stays 32-bit and silently wraps near 2**31.  Host capacity /
flop / byte accumulators must therefore widen at the fetch boundary
(``int(...)`` / ``np.int64(...)``) before arithmetic: 2 * nnz * 8 bytes
overflows int32 for matrices this engine already serves.

The rule tracks names assigned from narrowing producers and flags
arithmetic flowing into accumulator-named targets (``*_bytes``,
``*flops*``, ``total_*``, ``*nnz*``, ``cap*``, ...) when the narrow
subexpression is not wrapped in a widening call.  Traced functions are
skipped — device math is int32 by design; the rule polices the host
side only.
"""

from __future__ import annotations

import ast
import re
from typing import List, Set

from .callgraph import CallGraph, resolve_dotted
from .core import Finding, Project

RULES = {
    "INT001": "numpy int32 value flows into a host accumulator unwidened",
}

_ACC_RE = re.compile(
    r"(bytes|flop|nnz|prod|cap|total|count|acc|size|sum)", re.IGNORECASE)

_WIDENERS = {"int", "numpy.int64", "numpy.uint64", "float"}
_NARROW_PRODUCERS = {"jax.device_get", "numpy.asarray", "numpy.array",
                     "numpy.int32", "numpy.uint32"}


def run(project: Project, graph: CallGraph) -> List[Finding]:
    traced_nodes = {fn.node for fn in graph.traced}
    findings: List[Finding] = []
    for sf in sorted(project.iter_files(), key=lambda s: s.relpath):
        mi = graph.modules[sf.modname]
        for fn, scope in mi.functions:
            if fn.node in traced_nodes:
                continue
            findings.extend(_check_function(fn, mi))
    return findings


def _is_narrow_call(node: ast.Call, mi) -> bool:
    dotted = resolve_dotted(node.func, mi)
    if dotted in _NARROW_PRODUCERS:
        return True
    # x.astype(np.int32) / x.astype("int32")
    if isinstance(node.func, ast.Attribute) and node.func.attr == "astype":
        for arg in node.args:
            d = resolve_dotted(arg, mi)
            if d in {"numpy.int32", "numpy.uint32"}:
                return True
            if isinstance(arg, ast.Constant) and arg.value in ("int32", "uint32"):
                return True
    return False


def _is_widener(node: ast.Call, mi) -> bool:
    if isinstance(node.func, ast.Name) and node.func.id in {"int", "float"}:
        return True
    dotted = resolve_dotted(node.func, mi)
    return dotted in _WIDENERS


def _check_function(fn, mi) -> List[Finding]:
    findings: List[Finding] = []
    narrow_vars: Set[str] = set()

    def expr_narrow(node: ast.AST, widened: bool = False) -> bool:
        """True if *node* contains an unwidened narrow value."""
        if isinstance(node, ast.Call):
            if _is_widener(node, mi):
                return False  # everything below is widened
            if _is_narrow_call(node, mi):
                return not widened
            return any(expr_narrow(a, widened) for a in node.args)
        if isinstance(node, ast.Name):
            return node.id in narrow_vars and not widened
        if isinstance(node, ast.Subscript):
            return expr_narrow(node.value, widened)
        if isinstance(node, ast.BinOp):
            return expr_narrow(node.left, widened) or \
                expr_narrow(node.right, widened)
        if isinstance(node, ast.UnaryOp):
            return expr_narrow(node.operand, widened)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(expr_narrow(e, widened) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return expr_narrow(node.body, widened) or \
                expr_narrow(node.orelse, widened)
        if isinstance(node, ast.Attribute):
            # attribute chains off narrow values (e.g. fetched.sum())
            return expr_narrow(node.value, widened)
        return False

    # pass 1: which locals hold narrow values?
    for _ in range(4):
        before = len(narrow_vars)
        for node in ast.walk(fn.node):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn.node:
                continue
            if isinstance(node, ast.Assign) and expr_narrow(node.value):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        narrow_vars.add(tgt.id)
            elif isinstance(node, ast.AnnAssign) and node.value is not None \
                    and isinstance(node.target, ast.Name) \
                    and expr_narrow(node.value):
                narrow_vars.add(node.target.id)
        if len(narrow_vars) == before:
            break

    # pass 2: narrow arithmetic flowing into accumulator-named targets
    for node in ast.walk(fn.node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn.node:
            continue
        target_name = None
        rhs = None
        arithmetic = False
        if isinstance(node, ast.AugAssign) and isinstance(node.target, ast.Name):
            target_name, rhs, arithmetic = node.target.id, node.value, True
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            target_name, rhs = node.targets[0].id, node.value
            arithmetic = isinstance(rhs, ast.BinOp)
        if target_name is None or rhs is None or not arithmetic:
            continue
        if not _ACC_RE.search(target_name):
            continue
        if expr_narrow(rhs):
            findings.append(Finding(
                rule="INT001", path=fn.sf.relpath,
                line=node.lineno, col=node.col_offset,
                message=f"accumulator `{target_name}` absorbs a numpy-narrow "
                        "(int32) value without widening: host arithmetic "
                        "wraps at 2**31",
                hint="widen at the fetch boundary: wrap the device-fetched "
                     "subscript/scalar in int(...) or np.int64(...) before "
                     "the arithmetic",
            ))
    return findings
