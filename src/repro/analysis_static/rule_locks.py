"""LCK — lock-order cycles and guarded-field races.

The engine now has four lock-holding subsystems (``Arena``,
``PlanCache``, ``Telemetry``'s registry/event log, ``SpgemmService``)
whose locks nest across objects (cache eviction forfeits arena leases
while holding the cache lock).  Two mechanical checks keep that safe:

* ``LCK001`` — **ordering cycles**: a lock graph with an edge
  ``(C, L) -> (D, M)`` whenever a method of class ``C`` can call into a
  lock-acquiring method of class ``D`` while holding ``L``.  Any cycle
  is a potential deadlock under concurrent callers.  Cross-object
  attribute types are inferred from ``__init__`` (constructor calls,
  annotated parameters, and factory calls with return annotations).
* ``LCK002`` — **guarded-field races**: fields annotated
  ``# guarded-by: <lock>`` on their ``__init__`` assignment (or class
  body) must only be written inside a ``with self.<lock>:`` block.
  ``__init__`` is exempt (no concurrency before construction returns)
  and so are methods named ``*_locked`` — the repo convention for
  "caller already holds the lock" helpers (``PlanCache._insert_locked``).
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .callgraph import CallGraph, FuncInfo, resolve_dotted
from .core import GUARDED_BY_RE, Finding, Project, SourceFile

RULES = {
    "LCK001": "lock-ordering cycle across lock-holding classes",
    "LCK002": "write to a guarded-by field outside its lock",
}

# self.<field>.<mutator>(...) counts as a write to the field
_MUTATORS = {
    "append", "appendleft", "extend", "insert", "remove", "pop", "popitem",
    "popleft", "clear", "update", "add", "discard", "setdefault", "sort",
    "reverse",
}

_LOCK_FACTORIES = {"threading.Lock", "threading.RLock", "Lock", "RLock"}


@dataclass
class ClassLocks:
    name: str
    sf: SourceFile
    node: ast.ClassDef
    locks: Set[str] = field(default_factory=set)             # attr names
    guarded: Dict[str, str] = field(default_factory=dict)    # field -> lock
    # attr -> class name (for cross-object lock edges)
    attr_types: Dict[str, str] = field(default_factory=dict)
    methods: Dict[str, ast.AST] = field(default_factory=dict)

    def acquiring_methods(self) -> Dict[str, Set[str]]:
        """method name -> set of own locks it acquires anywhere."""
        out: Dict[str, Set[str]] = {}
        for name, node in self.methods.items():
            acquired = set()
            for sub in ast.walk(node):
                if isinstance(sub, ast.With):
                    for item in sub.items:
                        lock = _self_lock_attr(item.context_expr, self.locks)
                        if lock:
                            acquired.add(lock)
            if acquired:
                out[name] = acquired
        return out


def _self_lock_attr(expr: ast.AST, locks: Set[str]) -> Optional[str]:
    if isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name) \
            and expr.value.id == "self" and expr.attr in locks:
        return expr.attr
    return None


def _annotation_name(node: Optional[ast.AST]) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.split(".")[-1].strip("'\" ")
    if isinstance(node, ast.Subscript):  # Optional[Arena] and friends
        inner = node.slice
        if isinstance(inner, ast.Tuple) and inner.elts:
            inner = inner.elts[0]
        return _annotation_name(inner)
    return None


def _collect_classes(project: Project, graph: CallGraph) -> Dict[str, ClassLocks]:
    """All classes that own a threading lock, keyed by class name
    (class names are unique across this package)."""
    classes: Dict[str, ClassLocks] = {}
    factories: Dict[str, str] = {}  # function name -> returned class name

    for sf in project.iter_files():
        mi = graph.modules[sf.modname]
        for node in sf.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                ret = _annotation_name(node.returns)
                if ret:
                    factories[node.name] = ret

    for sf in project.iter_files():
        mi = graph.modules[sf.modname]
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            info = ClassLocks(name=node.name, sf=sf, node=node)
            for stmt in node.body:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    info.methods[stmt.name] = stmt
                elif isinstance(stmt, ast.AnnAssign) and \
                        isinstance(stmt.target, ast.Name):
                    m = GUARDED_BY_RE.search(sf.line_text(stmt.lineno))
                    if m:
                        info.guarded[stmt.target.id] = m.group(1)
                    ann = _annotation_name(stmt.annotation)
                    if ann:
                        info.attr_types[stmt.target.id] = ann

            init = info.methods.get("__init__")
            if init is not None:
                param_ann = {}
                all_args = list(getattr(init.args, "posonlyargs", [])) \
                    + list(init.args.args) + list(init.args.kwonlyargs)
                for a in all_args:
                    ann = _annotation_name(a.annotation)
                    if ann:
                        param_ann[a.arg] = ann
                for stmt in ast.walk(init):
                    if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                        continue
                    targets = stmt.targets if isinstance(stmt, ast.Assign) \
                        else [stmt.target]
                    value = stmt.value
                    for tgt in targets:
                        if not (isinstance(tgt, ast.Attribute)
                                and isinstance(tgt.value, ast.Name)
                                and tgt.value.id == "self"):
                            continue
                        attr = tgt.attr
                        m = GUARDED_BY_RE.search(sf.line_text(tgt.lineno))
                        if m:
                            info.guarded[attr] = m.group(1)
                        if value is None:
                            continue
                        if isinstance(value, ast.Call):
                            dotted = resolve_dotted(value.func, mi) or ""
                            tail = dotted.split(".")[-1] if dotted else ""
                            if dotted in _LOCK_FACTORIES or \
                                    (dotted.startswith("threading.")
                                     and tail in {"Lock", "RLock"}):
                                info.locks.add(attr)
                            elif tail in factories:
                                info.attr_types[attr] = factories[tail]
                            elif tail and tail[0].isupper():
                                info.attr_types[attr] = tail
                        elif isinstance(value, ast.Name) and \
                                value.id in param_ann:
                            info.attr_types[attr] = param_ann[value.id]
                        elif isinstance(value, (ast.IfExp, ast.BoolOp)):
                            for sub in ast.walk(value):
                                if isinstance(sub, ast.Name) and \
                                        sub.id in param_ann:
                                    info.attr_types[attr] = param_ann[sub.id]
                                    break
            if info.locks:
                classes[info.name] = info
    return classes


class _HeldLockVisitor(ast.NodeVisitor):
    """Walks a method body tracking which of the class's own locks are
    held, invoking ``on_node(node, held)`` for every statement/expr."""

    def __init__(self, info: ClassLocks, on_node):
        self.info = info
        self.on_node = on_node
        self.held: List[str] = []

    def visit_With(self, node: ast.With) -> None:
        acquired = []
        for item in node.items:
            lock = _self_lock_attr(item.context_expr, self.info.locks)
            if lock:
                acquired.append(lock)
        self.held.extend(acquired)
        for stmt in node.body:
            self.visit(stmt)
        for _ in acquired:
            self.held.pop()

    def visit_FunctionDef(self, node) -> None:
        # a nested def runs later, possibly without the lock: analyze it
        # with an empty held-set (conservative for LCK002's purposes)
        saved, self.held = self.held, []
        self.generic_visit(node)
        self.held = saved

    visit_AsyncFunctionDef = visit_FunctionDef

    def generic_visit(self, node) -> None:
        self.on_node(node, tuple(self.held))
        super().generic_visit(node)


def run(project: Project, graph: CallGraph) -> List[Finding]:
    classes = _collect_classes(project, graph)
    findings: List[Finding] = []
    findings.extend(_check_guarded_writes(classes))
    findings.extend(_check_lock_order(classes))
    return findings


# ---------------------------------------------------------------------------
# LCK002 — guarded-field writes
# ---------------------------------------------------------------------------

def _check_guarded_writes(classes: Dict[str, ClassLocks]) -> List[Finding]:
    findings: List[Finding] = []
    for info in classes.values():
        if not info.guarded:
            continue
        for mname, mnode in sorted(info.methods.items()):
            if mname == "__init__":
                continue
            caller_holds = set(info.locks) if mname.endswith("_locked") else set()

            def on_node(node, held, _m=mname):
                held_set = set(held) | caller_holds
                write = _guarded_write(node, info)
                if write is None:
                    return
                fieldname, lock = write
                if lock in held_set:
                    return
                findings.append(Finding(
                    rule="LCK002", path=info.sf.relpath,
                    line=node.lineno, col=node.col_offset,
                    message=f"`{info.name}.{_m}` writes `self.{fieldname}` "
                            f"(guarded-by: {lock}) without holding "
                            f"`self.{lock}`",
                    hint=f"wrap the write in `with self.{lock}:`, or rename "
                         "the method with a `_locked` suffix if every caller "
                         "already holds the lock",
                ))

            _HeldLockVisitor(info, on_node).visit(mnode)
    return findings


def _guarded_write(node: ast.AST, info: ClassLocks) -> Optional[Tuple[str, str]]:
    """(field, guarding lock) when *node* writes a guarded self-field."""

    def self_field(expr: ast.AST) -> Optional[str]:
        if isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name) \
                and expr.value.id == "self" and expr.attr in info.guarded:
            return expr.attr
        return None

    if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
        targets = node.targets if isinstance(node, ast.Assign) else [node.target]
        for tgt in targets:
            f = self_field(tgt)
            if f is None and isinstance(tgt, ast.Subscript):
                f = self_field(tgt.value)  # self.d[k] = v
            if f is not None:
                return f, info.guarded[f]
    elif isinstance(node, ast.Delete):
        for tgt in node.targets:
            f = self_field(tgt)
            if f is None and isinstance(tgt, ast.Subscript):
                f = self_field(tgt.value)
            if f is not None:
                return f, info.guarded[f]
    elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
            and node.func.attr in _MUTATORS:
        f = self_field(node.func.value)
        if f is not None:
            return f, info.guarded[f]
    return None


# ---------------------------------------------------------------------------
# LCK001 — lock-order cycles
# ---------------------------------------------------------------------------

def _check_lock_order(classes: Dict[str, ClassLocks]) -> List[Finding]:
    # edges: (cls, lock) -> set of ((cls, lock), site) it may acquire while held
    edges: Dict[Tuple[str, str], Dict[Tuple[str, str], Tuple[str, int]]] = {}
    acquiring = {name: info.acquiring_methods() for name, info in classes.items()}

    for info in classes.values():
        for mname, mnode in info.methods.items():
            base_held = [(info.name, lk) for lk in sorted(info.locks)] \
                if mname.endswith("_locked") else []

            def on_node(node, held, _base=tuple(base_held)):
                held_keys = list(_base) + [(info.name, lk) for lk in held]
                if not held_keys or not isinstance(node, ast.Call):
                    return
                for target in _call_lock_targets(node, info, classes, acquiring):
                    site = (info.sf.relpath, node.lineno)
                    for src in held_keys:
                        if src == target:
                            continue
                        edges.setdefault(src, {}).setdefault(target, site)

            _HeldLockVisitor(info, on_node).visit(mnode)

    # DFS for cycles over the (class, lock) graph
    findings: List[Finding] = []
    seen_cycles: Set[Tuple[Tuple[str, str], ...]] = set()
    for start in sorted(edges):
        stack = [(start, [start])]
        while stack:
            cur, path = stack.pop()
            for nxt, site in sorted(edges.get(cur, {}).items()):
                if nxt == path[0]:
                    cycle = tuple(sorted(path))
                    if cycle in seen_cycles:
                        continue
                    seen_cycles.add(cycle)
                    order = " -> ".join(f"{c}.{l}" for c, l in path + [nxt])
                    findings.append(Finding(
                        rule="LCK001", path=site[0], line=site[1], col=0,
                        message=f"lock-ordering cycle: {order} — concurrent "
                                "callers entering from different points can "
                                "deadlock",
                        hint="impose a global acquisition order (acquire the "
                             "outer lock first everywhere) or release the "
                             "first lock before calling into the other class",
                    ))
                elif nxt not in path and len(path) < 6:
                    stack.append((nxt, path + [nxt]))
    return findings


def _call_lock_targets(call: ast.Call, info: ClassLocks,
                       classes: Dict[str, ClassLocks],
                       acquiring: Dict[str, Dict[str, Set[str]]]):
    """(class, lock) pairs this call may acquire."""
    func = call.func
    out = []
    if isinstance(func, ast.Attribute):
        base = func.value
        # self.other.method(...) where self.other: KnownLockClass
        if isinstance(base, ast.Attribute) and isinstance(base.value, ast.Name) \
                and base.value.id == "self":
            target_cls = info.attr_types.get(base.attr)
            if target_cls in classes:
                for lk in acquiring.get(target_cls, {}).get(func.attr, ()):  # type: ignore[arg-type]
                    out.append((target_cls, lk))
        # self.method(...) acquiring a (different) own lock
        elif isinstance(base, ast.Name) and base.id == "self":
            for lk in acquiring.get(info.name, {}).get(func.attr, ()):
                out.append((info.name, lk))
    return out
