"""Rule registry, runner, and baseline diffing for opslint."""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from . import rule_donation, rule_intwidth, rule_kernel, rule_locks, rule_trace
from .callgraph import build_callgraph
from .core import Finding, Project, is_suppressed, load_project

_RULE_MODULES = (rule_trace, rule_donation, rule_locks, rule_intwidth,
                 rule_kernel)

ALL_RULES: Dict[str, str] = {}
for _mod in _RULE_MODULES:
    ALL_RULES.update(_mod.RULES)


def run_project(project: Project,
                rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run every rule family over *project*; suppressions applied."""
    graph = build_callgraph(project)
    selected = set(rules) if rules else None
    findings: List[Finding] = []
    for mod in _RULE_MODULES:
        if selected is not None and not (set(mod.RULES) & selected):
            continue
        for f in mod.run(project, graph):
            if selected is not None and f.rule not in selected:
                continue
            sf = project.files.get(f.path)
            if sf is not None and is_suppressed(sf, f):
                continue
            findings.append(f)
    findings.sort(key=lambda f: f.key())
    return findings


def run_paths(paths: Sequence[str], root: Optional[str] = None,
              rules: Optional[Sequence[str]] = None) -> List[Finding]:
    return run_project(load_project(paths, root=root), rules=rules)


def diff_against_baseline(
        findings: Sequence[Finding],
        baseline: Sequence[Finding]) -> Tuple[List[Finding], List[Finding]]:
    """(new, fixed): findings not in the baseline, and baseline entries
    no longer present (candidates for a baseline refresh)."""
    base_keys = {f.key() for f in baseline}
    cur_keys = {f.key() for f in findings}
    new = [f for f in findings if f.key() not in base_keys]
    fixed = [f for f in baseline if f.key() not in cur_keys]
    return new, fixed
