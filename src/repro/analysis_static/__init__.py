"""opslint — static analysis for the OpSparse SpGEMM engine.

An AST-based rule engine over ``src/repro`` that mechanically checks
the invariants this repo otherwise enforces by convention and review:

* **trace-safety** (``TRC001``/``TRC002``) — no host syncs and no
  data-dependent Python branching inside functions reachable from the
  jitted steady-state call graph (seeded from ``jax.jit`` /
  ``pallas_call`` sites, propagated through a conservative
  intra-package call graph with per-call-site taint).
* **donation discipline** (``DON001``) — a binding passed in a
  ``donate_argnums`` position is consumed by XLA; any later read of
  that binding aliases freed memory (the PR 7 arena-alias contract).
* **lock order / races** (``LCK001``/``LCK002``) — a lock graph built
  from ``threading.Lock``/``RLock`` acquisitions reports ordering
  cycles, and writes to fields annotated ``# guarded-by: <lock>``
  outside a ``with`` of that lock are flagged.
* **host-int width** (``INT001``) — numpy int32-producing expressions
  flowing unwidened into capacity/flop/byte accumulators (automates
  the PR 5 manual audit).
* **kernel budget** (``KRN001``/``KRN002``) — Pallas tile shapes and
  bucket constants that violate the pow-2 / ``PACK_TILE_ENTRIES``
  VMEM invariants.

CLI::

    python -m repro.analysis_static src/repro --fail-on-new \
        --baseline opslint_baseline.json --format json

Findings carry ``file:line``, a rule id, and a fix hint.  A checked-in
baseline makes CI fail only on *new* findings; false positives are
suppressed inline with ``# opslint: disable=<rule> -- reason``.
"""

from .core import (  # noqa: F401
    Finding,
    Project,
    SourceFile,
    load_baseline,
    load_project,
    save_baseline,
)
from .engine import ALL_RULES, diff_against_baseline, run_paths, run_project  # noqa: F401

__all__ = [
    "Finding",
    "Project",
    "SourceFile",
    "ALL_RULES",
    "run_paths",
    "run_project",
    "load_project",
    "load_baseline",
    "save_baseline",
    "diff_against_baseline",
]
