"""DON001 — donation discipline (the PR 7 arena-alias contract).

A buffer passed in a ``donate_argnums`` position is consumed: XLA may
alias its memory for the outputs, so any later read of the donated
binding observes freed/overwritten storage.  In this repo donated
buffers come out of the shared workspace ``Arena``, which makes a
read-after-donation a cross-request data race, not just a local bug.

The rule collects every donating callable —

* defs decorated ``@partial(jax.jit, ..., donate_argnums=...)``
  (``bin_rows_into`` donates its scratch), and
* bindings assigned ``name = jax.jit(fn, donate_argnums=...)``
  (``_exclusive_sum`` donates the nnz buffer) —

then, at each call site, maps the donated argnums to argument
expressions and flags any later load of that binding inside the same
function, stopping at a rebind (``x = f(x)`` is the blessed pattern:
the old binding dies at the call).  The path analysis is a linear
source-order approximation, which is exactly how the engine's
straight-line dispatch bodies read.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from .callgraph import CallGraph, FuncInfo, JitWrapper
from .core import Finding, Project

RULES = {
    "DON001": "read of a donated binding after the donating call",
}


def run(project: Project, graph: CallGraph) -> List[Finding]:
    findings: List[Finding] = []
    for modname, mi in sorted(graph.modules.items()):
        for fn, scope in mi.functions:
            findings.extend(_check_function(fn, mi, graph))
    return findings


def _donor_for_call(call: ast.Call, mi, graph: CallGraph) -> Optional[JitWrapper]:
    func = call.func
    name = None
    if isinstance(func, ast.Name):
        name = func.id
    elif isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name):
        # module-qualified call to a donating binding: mod._exclusive_sum(x)
        target_mod = mi.module_aliases.get(func.value.id)
        if target_mod is None and func.value.id in mi.symbol_imports:
            m, s = mi.symbol_imports[func.value.id]
            target_mod = f"{m}.{s}"
        if target_mod is not None:
            return graph.donors.get((target_mod, func.attr))
        return None
    if name is None:
        return None
    wrapper = graph.donors.get((mi.sf.modname, name))
    if wrapper is not None:
        return wrapper
    # decorated donating defs, resolved through imports or local scope
    if name in mi.symbol_imports:
        mod, sym = mi.symbol_imports[name]
        other = graph.modules.get(mod)
        if other is not None:
            target = other.scope.defs.get(sym)
            if target is not None and target in graph.donor_defs:
                return graph.donor_defs[target]
        return None
    for candidate, wrapper in graph.donor_defs.items():
        if candidate.sf.modname == mi.sf.modname and candidate.name == name:
            return wrapper
    return None


def _chain_str(node: ast.AST) -> Optional[str]:
    """Dotted string for a Name or simple attribute chain
    (``lease.i32`` -> "lease.i32"); None for anything more complex."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _donated_arg_names(call: ast.Call, wrapper: JitWrapper) -> List[str]:
    """Bindings (names or simple attribute chains) in donated positions."""
    params = wrapper.target.params if wrapper.target is not None else []
    out = []
    for pos in wrapper.donate_nums:
        arg = None
        if pos < len(call.args):
            arg = call.args[pos]
        elif pos < len(params):
            pname = params[pos]
            for kw in call.keywords:
                if kw.arg == pname:
                    arg = kw.value
        if arg is not None:
            chain = _chain_str(arg)
            if chain is not None:
                out.append(chain)
    return out


def _check_function(fn: FuncInfo, mi, graph: CallGraph) -> List[Finding]:
    findings: List[Finding] = []
    # gather (position, kind, name, node) events for every interesting name
    donations: List[Tuple[Tuple[int, int], str, ast.Call]] = []
    for node in ast.walk(fn.node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn.node:
            continue
        if isinstance(node, ast.Call):
            wrapper = _donor_for_call(node, mi, graph)
            if wrapper is None:
                continue
            for name in _donated_arg_names(node, wrapper):
                donations.append(((node.lineno, node.col_offset), name, node))
    if not donations:
        return findings

    loads: Dict[str, List[Tuple[Tuple[int, int], ast.AST]]] = {}
    stores: Dict[str, List[Tuple[int, int]]] = {}
    for node in ast.walk(fn.node):
        if isinstance(node, ast.Name):
            pos = (node.lineno, node.col_offset)
            if isinstance(node.ctx, ast.Load):
                loads.setdefault(node.id, []).append((pos, node))
            else:  # Store / Del both kill the old binding
                stores.setdefault(node.id, []).append(pos)
        elif isinstance(node, ast.Attribute):
            chain = _chain_str(node)
            if chain is None or "." not in chain:
                continue
            pos = (node.lineno, node.col_offset)
            if isinstance(node.ctx, ast.Load):
                loads.setdefault(chain, []).append((pos, node))
            else:
                stores.setdefault(chain, []).append(pos)

    for call_pos, name, call in donations:
        # first rebind at/after the donating statement kills the binding
        # (covers the `x = f(x)` idiom: the Assign target shares the call's
        # line but sits at an earlier column, so compare by line only)
        kill = min((p for p in stores.get(name, []) if p[0] >= call_pos[0]),
                   default=None)
        for pos, load in sorted(loads.get(name, [])):
            if pos <= call_pos:
                continue
            if _inside(call, load):
                continue  # the donating call's own argument
            if kill is not None and pos > kill:
                break
            findings.append(Finding(
                rule="DON001", path=fn.sf.relpath,
                line=load.lineno, col=load.col_offset,
                message=f"`{name}` is read after being donated at line "
                        f"{call.lineno} (donate_argnums): the buffer may "
                        "alias freed workspace memory",
                hint="rebind the result over the donated name "
                     f"(`{name} = ...`), or drop donation for this argument",
            ))
    return findings


def _inside(outer: ast.AST, node: ast.AST) -> bool:
    return any(child is node for child in ast.walk(outer))
