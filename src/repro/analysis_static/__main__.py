"""opslint CLI: ``python -m repro.analysis_static [paths...]``.

Exit status: 0 when clean (or when ``--fail-on-new`` finds nothing new
vs the baseline), 1 otherwise, 2 on usage errors.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from .core import Finding, load_baseline, load_project, save_baseline
from .engine import ALL_RULES, diff_against_baseline, run_project

DEFAULT_BASELINE = "opslint_baseline.json"


def _emit(findings: List[Finding], fmt: str, stream=None) -> None:
    stream = stream or sys.stdout
    if fmt == "json":
        payload = {"findings": [f.to_json() for f in findings],
                   "count": len(findings)}
        print(json.dumps(payload, indent=2), file=stream)
    else:
        for f in findings:
            print(f.format_text(), file=stream)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="opslint",
        description="Static analysis for the OpSparse SpGEMM engine: "
                    "trace-safety, donation discipline, lock order, "
                    "host-int width, kernel budgets.",
    )
    parser.add_argument("paths", nargs="*", default=["src/repro"],
                        help="files or directories to lint "
                             "(default: src/repro)")
    parser.add_argument("--baseline", default=None,
                        help="baseline JSON; with --fail-on-new, only "
                             "findings absent from it fail the run "
                             f"(default: {DEFAULT_BASELINE} if present)")
    parser.add_argument("--fail-on-new", action="store_true",
                        help="exit 1 only on findings not in the baseline")
    parser.add_argument("--write-baseline", metavar="PATH", default=None,
                        help="write the current findings as a new baseline "
                             "and exit 0")
    parser.add_argument("--format", choices=("text", "json"), default="text",
                        dest="fmt", help="output format (default: text)")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule ids to run "
                             "(default: all)")
    parser.add_argument("--root", default=None,
                        help="project root for relative paths "
                             "(default: cwd)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(ALL_RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in ALL_RULES]
        if unknown:
            print(f"opslint: unknown rule id(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2

    missing = [p for p in args.paths if not Path(p).exists()]
    if missing:
        print(f"opslint: no such path: {', '.join(missing)}", file=sys.stderr)
        return 2

    project = load_project(args.paths, root=args.root)
    findings = run_project(project, rules=rules)

    if args.write_baseline:
        save_baseline(findings, args.write_baseline)
        print(f"opslint: wrote {len(findings)} finding(s) to "
              f"{args.write_baseline}")
        return 0

    baseline_path = args.baseline
    if baseline_path is None and Path(DEFAULT_BASELINE).exists():
        baseline_path = DEFAULT_BASELINE

    if args.fail_on_new:
        baseline = load_baseline(baseline_path) if baseline_path else []
        new, fixed = diff_against_baseline(findings, baseline)
        _emit(new, args.fmt)
        if args.fmt == "text":
            label = f" vs baseline {baseline_path}" if baseline_path else ""
            print(f"opslint: {len(findings)} finding(s), {len(new)} new"
                  f"{label}, {len(fixed)} fixed")
            if fixed:
                print("opslint: baseline entries no longer found "
                      "(refresh with --write-baseline):")
                for f in fixed:
                    print(f"  {f.path}:{f.line}: {f.rule}")
        return 1 if new else 0

    _emit(findings, args.fmt)
    if args.fmt == "text":
        print(f"opslint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
