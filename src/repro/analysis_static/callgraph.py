"""Conservative intra-package call graph seeded from jit / pallas sites.

The trace-safety and donation rules need to know (a) which functions
execute *under a JAX trace* in the steady state, and (b) which of their
parameters carry traced values (vs. static host config).  Both are
answered here without importing the package:

* **Seeds** — functions decorated ``@jax.jit`` / ``@partial(jax.jit,
  ...)``, functions wrapped at call sites (``name = jax.jit(fn, ...)``,
  ``return jax.jit(body)``), and kernels handed to
  ``pl.pallas_call(kernel, ...)``.  ``static_argnames`` /
  ``static_argnums`` mark host parameters; ``donate_argnums`` feeds the
  donation registry.
* **Propagation** — inside a traced function, a call to a function we
  can resolve (same scope chain, same module, or an imported repro
  module) marks the callee traced too.  Taint is per *call site*: only
  parameters that actually receive traced arguments become traced, so a
  schedule tuple threaded through a traced driver stays static and
  ``if not rows_cap:`` branches on it are not flagged.

Resolution is deliberately conservative: higher-order flow other than
the explicit jit/pallas wrappers is not followed, attribute loads off
traced objects are treated as static (CSR metadata like ``A.nrows`` is
aux data under jit), and unresolvable calls add no edges.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .core import Project, SourceFile

# Module names whose call results are traced values inside a jit region.
_TRACED_NAMESPACES = {
    "jax", "jax.numpy", "jax.lax", "jax.nn", "jax.scipy",
    "jax.experimental.pallas", "jax.experimental.pallas.tpu",
}

# Host coercions: their *call* is a trace hazard (TRC001 reports it) but
# the result is a host value, so taint does not flow through them.
_HOST_COERCIONS = {"int", "float", "bool", "len", "str"}


@dataclass(eq=False)
class FuncInfo:
    """One function or method definition anywhere in the project."""

    node: ast.AST                      # FunctionDef / AsyncFunctionDef
    sf: SourceFile
    qualname: str                      # "Class.method" / "outer.inner"
    cls: Optional[str] = None          # enclosing class name, if a method

    @property
    def name(self) -> str:
        return self.node.name

    @property
    def params(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in getattr(a, "posonlyargs", [])]
        names += [p.arg for p in a.args]
        return names

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<FuncInfo {self.sf.modname}:{self.qualname}>"


class Scope:
    """Lexical scope for name → definition resolution (class scopes are
    skipped on lookup, matching Python semantics)."""

    def __init__(self, kind: str, parent: Optional["Scope"] = None):
        self.kind = kind               # "module" | "class" | "function"
        self.parent = parent
        self.defs: Dict[str, FuncInfo] = {}
        self.assigned_callables: Dict[str, "JitWrapper"] = {}

    def lookup(self, name: str) -> Optional[FuncInfo]:
        scope: Optional[Scope] = self
        while scope is not None:
            if scope.kind != "class" and name in scope.defs:
                return scope.defs[name]
            scope = scope.parent
        return None

    def lookup_wrapper(self, name: str) -> Optional["JitWrapper"]:
        scope: Optional[Scope] = self
        while scope is not None:
            if scope.kind != "class" and name in scope.assigned_callables:
                return scope.assigned_callables[name]
            scope = scope.parent
        return None


@dataclass
class JitWrapper:
    """``name = jax.jit(fn, donate_argnums=...)`` — a wrapped callable
    binding whose call sites follow jit semantics."""

    target: Optional[FuncInfo]         # the wrapped def, when resolvable
    static_names: Tuple[str, ...] = ()
    static_nums: Tuple[int, ...] = ()
    donate_nums: Tuple[int, ...] = ()
    line: int = 0


@dataclass
class ModuleIndex:
    sf: SourceFile
    scope: Scope
    # import alias -> full module name ("np" -> "numpy", "pl" -> "...pallas")
    module_aliases: Dict[str, str] = field(default_factory=dict)
    # from-imported symbol -> (module, symbol)
    symbol_imports: Dict[str, Tuple[str, str]] = field(default_factory=dict)
    classes: Dict[str, Dict[str, FuncInfo]] = field(default_factory=dict)
    # every FuncInfo in the module, with its *enclosing* scope for lookups
    functions: List[Tuple[FuncInfo, Scope]] = field(default_factory=list)


@dataclass
class CallGraph:
    project: Project
    modules: Dict[str, ModuleIndex] = field(default_factory=dict)
    # traced function -> names of parameters carrying traced values
    traced: Dict[FuncInfo, Set[str]] = field(default_factory=dict)
    # jit wrappers with donate_argnums, keyed by (modname, binding name)
    donors: Dict[Tuple[str, str], JitWrapper] = field(default_factory=dict)
    # decorated defs that themselves donate (call sites use the def name)
    donor_defs: Dict[FuncInfo, JitWrapper] = field(default_factory=dict)
    # fn -> param indices that the fn jit-wraps or calls under jit
    # (one-level higher-order: `_finish_executable(plan, body)` seeds `body`)
    wrapper_params: Dict[FuncInfo, Set[int]] = field(default_factory=dict)

    def module_for(self, sf: SourceFile) -> ModuleIndex:
        return self.modules[sf.modname]

    def is_traced(self, fn: FuncInfo) -> bool:
        return fn in self.traced


# ---------------------------------------------------------------------------
# Name / attribute resolution helpers
# ---------------------------------------------------------------------------

def resolve_dotted(node: ast.AST, mi: ModuleIndex) -> Optional[str]:
    """Best-effort dotted name for an expression like ``jax.numpy.sum``
    or ``jnp.sum`` (aliases expanded), else None."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        base = cur.id
        full = mi.module_aliases.get(base)
        if full is not None:
            parts.append(full)
        elif base in mi.symbol_imports:
            mod, sym = mi.symbol_imports[base]
            parts.append(f"{mod}.{sym}")
        else:
            parts.append(base)
        return ".".join(reversed(parts))
    return None


def _is_jax_jit(node: ast.AST, mi: ModuleIndex) -> bool:
    dotted = resolve_dotted(node, mi)
    return dotted in {"jax.jit", "jax.api.jit"}


def _is_partial(node: ast.AST, mi: ModuleIndex) -> bool:
    dotted = resolve_dotted(node, mi)
    return dotted in {"functools.partial", "partial"}


def _is_pallas_call(node: ast.AST, mi: ModuleIndex) -> bool:
    dotted = resolve_dotted(node, mi)
    return bool(dotted) and dotted.endswith("pallas_call")


def _const_str_tuple(node: ast.AST) -> Tuple[str, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
        return tuple(out)
    return ()


def _const_int_tuple(node: ast.AST) -> Tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                out.append(elt.value)
        return tuple(out)
    return ()


def _jit_kwargs(call: ast.Call) -> Tuple[Tuple[str, ...], Tuple[int, ...], Tuple[int, ...]]:
    """(static_argnames, static_argnums, donate_argnums) from a jit call."""
    static_names: Tuple[str, ...] = ()
    static_nums: Tuple[int, ...] = ()
    donate: Tuple[int, ...] = ()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            static_names = _const_str_tuple(kw.value)
        elif kw.arg == "static_argnums":
            static_nums = _const_int_tuple(kw.value)
        elif kw.arg == "donate_argnums":
            donate = _const_int_tuple(kw.value)
    return static_names, static_nums, donate


# ---------------------------------------------------------------------------
# Module indexing
# ---------------------------------------------------------------------------

class _Indexer(ast.NodeVisitor):
    def __init__(self, mi: ModuleIndex):
        self.mi = mi
        self.scope_stack: List[Scope] = [mi.scope]
        self.class_stack: List[str] = []

    @property
    def scope(self) -> Scope:
        return self.scope_stack[-1]

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self.mi.module_aliases[alias.asname or alias.name.split(".")[0]] = (
                alias.name if alias.asname else alias.name.split(".")[0]
            )
            if alias.asname:
                self.mi.module_aliases[alias.asname] = alias.name

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module is None:
            return
        mod = node.module
        if node.level:  # relative import: resolve against this module's package
            pkg_parts = self.mi.sf.modname.split(".")[:-node.level]
            mod = ".".join(pkg_parts + [node.module]) if pkg_parts else node.module
        for alias in node.names:
            local = alias.asname or alias.name
            self.mi.symbol_imports[local] = (mod, alias.name)

    def _visit_func(self, node) -> None:
        info = FuncInfo(
            node=node, sf=self.mi.sf,
            qualname=".".join(self.class_stack + [node.name]) if self.class_stack
            else node.name,
            cls=self.class_stack[-1] if self.class_stack else None,
        )
        self.scope.defs[node.name] = info
        self.mi.functions.append((info, self.scope))
        if self.class_stack and len(self.scope_stack) >= 1 \
                and self.scope.kind == "class":
            self.mi.classes.setdefault(self.class_stack[-1], {})[node.name] = info
        inner = Scope("function", parent=self.scope)
        info.inner_scope = inner  # type: ignore[attr-defined]
        self.scope_stack.append(inner)
        for stmt in node.body:
            self.visit(stmt)
        self.scope_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.mi.classes.setdefault(node.name, {})
        cls_scope = Scope("class", parent=self.scope)
        self.scope_stack.append(cls_scope)
        self.class_stack.append(node.name)
        for stmt in node.body:
            self.visit(stmt)
        self.class_stack.pop()
        self.scope_stack.pop()

    def visit_Assign(self, node: ast.Assign) -> None:
        # name = jax.jit(fn, ...) / name = partial(jax.jit, ...)(..)? — the
        # former is the pattern this repo uses (`_exclusive_sum`).
        if isinstance(node.value, ast.Call):
            self._maybe_wrapper(node.targets, node.value)
        self.generic_visit(node)

    def _maybe_wrapper(self, targets, call: ast.Call) -> None:
        if not _is_jax_jit(call.func, self.mi):
            return
        target_fn: Optional[FuncInfo] = None
        if call.args and isinstance(call.args[0], ast.Name):
            target_fn = self.scope.lookup(call.args[0].id)
            if target_fn is None and call.args[0].id in self.mi.symbol_imports:
                pass  # cross-module wrap; resolved in the build pass
        static_names, static_nums, donate = _jit_kwargs(call)
        wrapper = JitWrapper(
            target=target_fn, static_names=static_names,
            static_nums=static_nums, donate_nums=donate, line=call.lineno,
        )
        for tgt in targets:
            if isinstance(tgt, ast.Name):
                self.scope.assigned_callables[tgt.id] = wrapper
        # stash for the seed pass
        self.mi.sf.tree.opslint_wrappers = getattr(  # type: ignore[attr-defined]
            self.mi.sf.tree, "opslint_wrappers", [])
        self.mi.sf.tree.opslint_wrappers.append((wrapper, call))  # type: ignore[attr-defined]


def index_module(sf: SourceFile) -> ModuleIndex:
    mi = ModuleIndex(sf=sf, scope=Scope("module"))
    _Indexer(mi).visit(sf.tree)
    return mi


# ---------------------------------------------------------------------------
# Seed discovery
# ---------------------------------------------------------------------------

def _decorator_seed(fn: FuncInfo, mi: ModuleIndex) -> Optional[JitWrapper]:
    """jit/partial(jit, ...) decorator on *fn*, if any."""
    for dec in fn.node.decorator_list:
        if _is_jax_jit(dec, mi):
            return JitWrapper(target=fn, line=dec.lineno)
        if isinstance(dec, ast.Call):
            if _is_jax_jit(dec.func, mi):
                sn, si, dn = _jit_kwargs(dec)
                return JitWrapper(target=fn, static_names=sn, static_nums=si,
                                  donate_nums=dn, line=dec.lineno)
            if _is_partial(dec.func, mi) and dec.args \
                    and _is_jax_jit(dec.args[0], mi):
                sn, si, dn = _jit_kwargs(dec)
                return JitWrapper(target=fn, static_names=sn, static_nums=si,
                                  donate_nums=dn, line=dec.lineno)
    return None


class _SeedScanner(ast.NodeVisitor):
    """Finds jit()/pallas_call() *call sites* whose wrapped function is a
    Name we can resolve — covers ``return jax.jit(body)`` and kernels."""

    def __init__(self, mi: ModuleIndex, graph: "CallGraph"):
        self.mi = mi
        self.graph = graph
        self.scope_stack: List[Scope] = [mi.scope]

    def _visit_func(self, node) -> None:
        for fn, scope in self.mi.functions:
            if fn.node is node:
                self.scope_stack.append(getattr(fn, "inner_scope", scope))
                break
        else:
            self.scope_stack.append(self.scope_stack[-1])
        self.generic_visit(node)
        self.scope_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_Call(self, node: ast.Call) -> None:
        scope = self.scope_stack[-1]
        fn: Optional[FuncInfo] = None
        if _is_jax_jit(node.func, self.mi) and node.args \
                and isinstance(node.args[0], ast.Name):
            fn = scope.lookup(node.args[0].id)
            if fn is not None:
                sn, si, dn = _jit_kwargs(node)
                _seed(self.graph, fn, static_names=sn, static_nums=si)
        elif _is_pallas_call(node.func, self.mi) and node.args \
                and isinstance(node.args[0], ast.Name):
            fn = scope.lookup(node.args[0].id)
            if fn is not None:
                # every kernel ref-param is a traced buffer
                _seed(self.graph, fn)
        else:
            # one-level higher-order: F(..., body, ...) where F jit-wraps
            # that parameter seeds the local def passed in
            callee = resolve_call(node, scope, self.mi, self.graph, None)
            wraps = self.graph.wrapper_params.get(callee) if callee else None
            if wraps:
                params = callee.params
                for idx in wraps:
                    arg = None
                    if idx < len(node.args):
                        arg = node.args[idx]
                    elif idx < len(params):
                        for kw in node.keywords:
                            if kw.arg == params[idx]:
                                arg = kw.value
                    if isinstance(arg, ast.Name):
                        target = scope.lookup(arg.id)
                        if target is not None:
                            _seed(self.graph, target)
        self.generic_visit(node)


def _seed(graph: CallGraph, fn: FuncInfo,
          static_names: Sequence[str] = (), static_nums: Sequence[int] = ()) -> None:
    params = fn.params
    tainted = set()
    for i, name in enumerate(params):
        if name in static_names or i in static_nums or name == "self":
            continue
        tainted.add(name)
    prev = graph.traced.get(fn)
    if prev is None or not tainted <= prev:
        graph.traced[fn] = (prev or set()) | tainted
        graph._dirty.append(fn)  # type: ignore[attr-defined]


# ---------------------------------------------------------------------------
# Taint analysis inside one function
# ---------------------------------------------------------------------------

class TaintResult:
    def __init__(self, tainted_names: Set[str],
                 calls: List[Tuple[ast.Call, Optional[FuncInfo], Set[int], Set[str]]]):
        self.tainted_names = tainted_names
        # (call node, resolved callee, tainted positional idxs, tainted kwarg names)
        self.calls = calls


def resolve_call(call: ast.Call, scope: Scope, mi: ModuleIndex,
                 graph: CallGraph, cls: Optional[str]) -> Optional[FuncInfo]:
    """Resolve a call's target to a project FuncInfo when possible."""
    func = call.func
    if isinstance(func, ast.Name):
        fn = scope.lookup(func.id)
        if fn is not None:
            return fn
        wrapper = scope.lookup_wrapper(func.id)
        if wrapper is not None and wrapper.target is not None:
            return wrapper.target
        if func.id in mi.symbol_imports:
            mod, sym = mi.symbol_imports[func.id]
            other = graph.modules.get(mod)
            if other is not None:
                return other.scope.defs.get(sym)
        return None
    if isinstance(func, ast.Attribute):
        base = func.value
        if isinstance(base, ast.Name):
            if base.id == "self" and cls is not None:
                methods = mi.classes.get(cls, {})
                return methods.get(func.attr)
            target_mod = mi.module_aliases.get(base.id)
            if target_mod is None and base.id in mi.symbol_imports:
                mod, sym = mi.symbol_imports[base.id]
                target_mod = f"{mod}.{sym}"
            if target_mod is not None:
                other = graph.modules.get(target_mod)
                if other is not None:
                    return other.scope.defs.get(func.attr)
    return None


def _namespace_is_traced(call: ast.Call, mi: ModuleIndex) -> bool:
    dotted = resolve_dotted(call.func, mi)
    if not dotted:
        return False
    head = dotted.rsplit(".", 1)[0]
    return head in _TRACED_NAMESPACES or dotted.startswith("jax.numpy.") \
        or dotted.startswith("jax.lax.")


def analyze_taint(fn: FuncInfo, tainted_params: Set[str], scope: Scope,
                  mi: ModuleIndex, graph: CallGraph) -> TaintResult:
    """Flow-insensitive taint: a name ever assigned a traced value is
    traced for the whole function (iterated to a small fixpoint)."""
    tainted: Set[str] = set(tainted_params)
    calls: List[Tuple[ast.Call, Optional[FuncInfo], Set[int], Set[str]]] = []

    def expr_tainted(node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in tainted
        if isinstance(node, ast.Subscript):
            return expr_tainted(node.value)
        if isinstance(node, ast.Call):
            func_name = node.func.id if isinstance(node.func, ast.Name) else None
            if func_name in _HOST_COERCIONS:
                return False
            if _namespace_is_traced(node, mi):
                return True
            if isinstance(node.func, ast.Attribute) and expr_tainted(node.func.value):
                return True  # method result of a traced object (x.astype, ...)
            # a traced callee fed only static args returns a host value
            # (resolve_interpret-style helpers) — taint needs tainted input
            return any(expr_tainted(a) for a in node.args) or \
                any(expr_tainted(kw.value) for kw in node.keywords)
        if isinstance(node, (ast.BinOp,)):
            return expr_tainted(node.left) or expr_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return expr_tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(expr_tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return expr_tainted(node.left) or \
                any(expr_tainted(c) for c in node.comparators)
        if isinstance(node, ast.IfExp):
            return expr_tainted(node.body) or expr_tainted(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(expr_tainted(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return expr_tainted(node.value)
        if isinstance(node, ast.NamedExpr):
            return expr_tainted(node.value)
        # Attribute loads are deliberately NOT tainted: pytree aux data
        # (A.nrows, schedule.row_buckets) is static under jit.
        return False

    def bind_targets(target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            tainted.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                bind_targets(elt)
        elif isinstance(target, ast.Starred):
            bind_targets(target.value)

    body_stmts = list(fn.node.body)
    for _ in range(8):  # fixpoint over out-of-order assignments
        before = len(tainted)
        for node in ast.walk(fn.node):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn.node:
                continue
            if isinstance(node, ast.Assign) and expr_tainted(node.value):
                for t in node.targets:
                    bind_targets(t)
            elif isinstance(node, ast.AnnAssign) and node.value is not None \
                    and expr_tainted(node.value):
                bind_targets(node.target)
            elif isinstance(node, ast.AugAssign) and \
                    (expr_tainted(node.value) or expr_tainted(node.target)):
                bind_targets(node.target)
            elif isinstance(node, ast.NamedExpr) and expr_tainted(node.value):
                bind_targets(node.target)
            elif isinstance(node, ast.For) and expr_tainted(node.iter):
                bind_targets(node.target)
            elif isinstance(node, ast.withitem) and node.optional_vars is not None \
                    and expr_tainted(node.context_expr):
                bind_targets(node.optional_vars)
        if len(tainted) == before:
            break

    for node in ast.walk(fn.node):
        if isinstance(node, ast.Call):
            callee = resolve_call(node, scope, mi, graph, fn.cls)
            t_pos = {i for i, a in enumerate(node.args) if expr_tainted(a)}
            t_kw = {kw.arg for kw in node.keywords
                    if kw.arg is not None and expr_tainted(kw.value)}
            calls.append((node, callee, t_pos, t_kw))

    return TaintResult(tainted, calls)


# ---------------------------------------------------------------------------
# Graph construction
# ---------------------------------------------------------------------------

def build_callgraph(project: Project) -> CallGraph:
    graph = CallGraph(project=project)
    graph._dirty = []  # type: ignore[attr-defined]

    for sf in project.iter_files():
        graph.modules[sf.modname] = index_module(sf)

    # which params does each function jit-wrap (or call under a jitted
    # nested def)?  Needed before the seed scan so cross-module call
    # sites of e.g. `_finish_executable(plan, body)` can seed `body`.
    for mi in graph.modules.values():
        for fn, _scope in mi.functions:
            idxs = _wrapper_param_indices(fn, mi)
            if idxs:
                graph.wrapper_params[fn] = idxs

    # seeds: decorators, wrapper assignments, jit()/pallas_call() call sites
    for mi in graph.modules.values():
        for fn, scope in mi.functions:
            wrapper = _decorator_seed(fn, mi)
            if wrapper is not None:
                _seed(graph, fn, static_names=wrapper.static_names,
                      static_nums=wrapper.static_nums)
                if wrapper.donate_nums:
                    graph.donor_defs[fn] = wrapper
        for wrapper, call in getattr(mi.sf.tree, "opslint_wrappers", []):
            if wrapper.target is None and call.args \
                    and isinstance(call.args[0], ast.Name) \
                    and call.args[0].id in mi.symbol_imports:
                mod, sym = mi.symbol_imports[call.args[0].id]
                other = graph.modules.get(mod)
                if other is not None:
                    wrapper.target = other.scope.defs.get(sym)
            if wrapper.target is not None:
                _seed(graph, wrapper.target, static_names=wrapper.static_names,
                      static_nums=wrapper.static_nums)
        _SeedScanner(mi, graph).visit(mi.sf.tree)
        # donation wrappers by binding name (module scope and nested)
        for scope in _all_scopes(mi):
            for name, wrapper in scope.assigned_callables.items():
                if wrapper.donate_nums:
                    graph.donors[(mi.sf.modname, name)] = wrapper

    # propagate tracedness through resolvable calls, per-call-site taint
    worklist = list(graph.traced.keys())
    seen_rounds = 0
    while worklist and seen_rounds < 10000:
        seen_rounds += 1
        fn = worklist.pop()
        mi = graph.modules.get(fn.sf.modname)
        if mi is None:
            continue
        scope = getattr(fn, "inner_scope", mi.scope)
        taint = analyze_taint(fn, graph.traced.get(fn, set()), scope, mi, graph)
        for call, callee, t_pos, t_kw in taint.calls:
            if callee is None or callee is fn:
                continue
            if _is_wrapper_machinery(call, mi):
                continue
            params = callee.params
            offset = 1 if params[:1] == ["self"] and _is_method_call(call) else 0
            new_tainted = set()
            for i in t_pos:
                idx = i + offset
                if idx < len(params):
                    new_tainted.add(params[idx])
            for kw in t_kw:
                if kw in params:
                    new_tainted.add(kw)
            prev = graph.traced.get(callee)
            if prev is None:
                graph.traced[callee] = set(new_tainted)
                worklist.append(callee)
            elif not new_tainted <= prev:
                prev |= new_tainted
                worklist.append(callee)
    return graph


def _wrapper_param_indices(fn: FuncInfo, mi: ModuleIndex) -> Set[int]:
    """Indices of *fn*'s parameters that it wraps in jax.jit (directly,
    ``return jax.jit(body)``) or calls from inside a jit-decorated
    nested def (``@jax.jit def run(...): return body(...)``)."""
    params = fn.params
    if not params:
        return set()
    idx_of = {name: i for i, name in enumerate(params)}
    out: Set[int] = set()
    jitted_nested: List[ast.AST] = []
    for node in ast.walk(fn.node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn.node:
            for dec in node.decorator_list:
                if _is_jax_jit(dec, mi) or (
                        isinstance(dec, ast.Call)
                        and (_is_jax_jit(dec.func, mi)
                             or (_is_partial(dec.func, mi) and dec.args
                                 and _is_jax_jit(dec.args[0], mi)))):
                    jitted_nested.append(node)
                    break
        elif isinstance(node, ast.Call) and _is_jax_jit(node.func, mi) \
                and node.args and isinstance(node.args[0], ast.Name) \
                and node.args[0].id in idx_of:
            out.add(idx_of[node.args[0].id])
    for nested in jitted_nested:
        for node in ast.walk(nested):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                    and node.func.id in idx_of:
                out.add(idx_of[node.func.id])
    return out


def _is_method_call(call: ast.Call) -> bool:
    return isinstance(call.func, ast.Attribute)


def _is_wrapper_machinery(call: ast.Call, mi: ModuleIndex) -> bool:
    """jit(fn) / pallas_call(kernel) sites already handled as seeds —
    the Name argument there is a function reference, not a data arg."""
    return _is_jax_jit(call.func, mi) or _is_pallas_call(call.func, mi) \
        or _is_partial(call.func, mi)


def _all_scopes(mi: ModuleIndex):
    yield mi.scope
    for fn, _ in mi.functions:
        inner = getattr(fn, "inner_scope", None)
        if inner is not None:
            yield inner


def function_scope(graph: CallGraph, fn: FuncInfo) -> Scope:
    mi = graph.modules[fn.sf.modname]
    return getattr(fn, "inner_scope", mi.scope)
