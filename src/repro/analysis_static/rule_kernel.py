"""KRN — kernel tile/bucket budget invariants.

The Pallas hash kernels budget VMEM around two module-level invariants:

* every table size, bin bucket, tile and block constant is a **power of
  two** — the pow-2 bucket ladder is what lets schedules round-trip
  through ``next_bucket`` bit-for-bit and lets ``rows_per_block_of``
  pack rows with exact divisibility (``KRN001``);
* pack/tile **entry budgets** are lane-aligned multiples of 128 (the
  VPU lane width) and fit a VMEM tile (``PACK_TILE_ENTRIES`` is
  ``8 * 128``); a mis-sized budget silently spills tiles (``KRN002``).

Both checks evaluate module-level ALL_CAPS constants whose names match
the tile/bucket vocabulary; simple constant arithmetic (``8 * 128``)
is folded.  Deliberately non-pow-2 constants (the GPU-shaved
``NUMERIC_TABLE_SIZES = (31, 255, ...)``) are suppressed inline with a
documented reason rather than special-cased here.
"""

from __future__ import annotations

import ast
import re
from typing import List, Optional

from .callgraph import CallGraph
from .core import Finding, Project

RULES = {
    "KRN001": "tile/bucket constant is not a power of two",
    "KRN002": "pack/tile entry budget is not lane-aligned or exceeds VMEM",
}

_POW2_NAME_RE = re.compile(
    r"(TABLE_SIZES|BUCKET|TILE|BLOCK|PACK)", re.IGNORECASE)
_BUDGET_NAME_RE = re.compile(r"(PACK|ENTRIES)", re.IGNORECASE)

_LANE = 128
# One int32 VMEM tile budget for packed tables: beyond this the pack
# ladder would overrun a tile and Mosaic starts spilling.
_MAX_TILE_ENTRIES = 64 * 1024


def _fold(node: ast.AST) -> Optional[int]:
    """Fold simple constant integer arithmetic (8 * 128, 1 << 10)."""
    if isinstance(node, ast.Constant):
        return node.value if isinstance(node.value, int) \
            and not isinstance(node.value, bool) else None
    if isinstance(node, ast.BinOp):
        left, right = _fold(node.left), _fold(node.right)
        if left is None or right is None:
            return None
        try:
            if isinstance(node.op, ast.Mult):
                return left * right
            if isinstance(node.op, ast.Add):
                return left + right
            if isinstance(node.op, ast.Sub):
                return left - right
            if isinstance(node.op, ast.FloorDiv):
                return left // right
            if isinstance(node.op, ast.Pow):
                return left ** right
            if isinstance(node.op, ast.LShift):
                return left << right
        except (ZeroDivisionError, OverflowError, ValueError):
            return None
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        inner = _fold(node.operand)
        return -inner if inner is not None else None
    return None


def _values(node: ast.AST) -> List[Optional[int]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return [_fold(e) for e in node.elts]
    return [_fold(node)]


def _is_pow2(v: int) -> bool:
    return v > 0 and (v & (v - 1)) == 0


def run(project: Project, graph: CallGraph) -> List[Finding]:
    findings: List[Finding] = []
    for sf in sorted(project.iter_files(), key=lambda s: s.relpath):
        for node in sf.tree.body:
            targets = []
            value = None
            if isinstance(node, ast.Assign):
                targets = [t for t in node.targets if isinstance(t, ast.Name)]
                value = node.value
            elif isinstance(node, ast.AnnAssign) and \
                    isinstance(node.target, ast.Name):
                targets = [node.target]
                value = node.value
            if not targets or value is None:
                continue
            for tgt in targets:
                name = tgt.id
                if not name.isupper():
                    continue
                vals = [v for v in _values(value) if v is not None]
                if not vals:
                    continue
                if _POW2_NAME_RE.search(name):
                    bad = [v for v in vals if not _is_pow2(v)]
                    if bad:
                        findings.append(Finding(
                            rule="KRN001", path=sf.relpath,
                            line=node.lineno, col=node.col_offset,
                            message=f"`{name}` contains non-power-of-two "
                                    f"value(s) {bad}: the pow-2 bucket ladder "
                                    "(next_bucket / rows_per_block_of) "
                                    "assumes exact pow-2 divisibility",
                            hint="round to the nearest power of two, or "
                                 "suppress with a documented reason if the "
                                 "size is deliberately shaved",
                        ))
                if _BUDGET_NAME_RE.search(name):
                    for v in vals:
                        if v % _LANE != 0:
                            findings.append(Finding(
                                rule="KRN002", path=sf.relpath,
                                line=node.lineno, col=node.col_offset,
                                message=f"`{name}` = {v} is not a multiple "
                                        f"of the {_LANE}-wide VPU lane: "
                                        "packed tiles would straddle lanes",
                                hint=f"size entry budgets in units of {_LANE}",
                            ))
                        elif v > _MAX_TILE_ENTRIES:
                            findings.append(Finding(
                                rule="KRN002", path=sf.relpath,
                                line=node.lineno, col=node.col_offset,
                                message=f"`{name}` = {v} exceeds the "
                                        f"{_MAX_TILE_ENTRIES}-entry VMEM "
                                        "tile budget",
                                hint="shrink the pack budget or split the "
                                     "tile across grid steps",
                            ))
    return findings
