"""Core opslint machinery: findings, project loading, suppressions, baseline.

Everything here is pure AST/text work — the analyzed package is never
imported, so the linter runs in any environment (no JAX needed) and is
safe to point at broken or half-written code.
"""

from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# `# opslint: disable=TRC001` or `# opslint: disable=TRC001,LCK002 -- reason`.
# The ``-- reason`` tail is strongly encouraged (review-enforced): a
# suppression without a reason is a finding waiting to come back.
_SUPPRESS_RE = re.compile(
    r"#\s*opslint:\s*disable=([A-Za-z0-9_,\s]+?)\s*(?:--\s*(?P<reason>.*))?$"
)

# `self.field = ...  # guarded-by: _lock` — ground truth for LCK002.
GUARDED_BY_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")

BASELINE_VERSION = 1


@dataclass(frozen=True)
class Finding:
    """One lint finding, stable enough to diff against a baseline."""

    rule: str          # e.g. "TRC001"
    path: str          # project-relative, posix separators
    line: int          # 1-based
    col: int           # 0-based (ast convention)
    message: str
    hint: str = ""     # concrete fix suggestion

    def key(self) -> Tuple[str, str, int, int]:
        return (self.rule, self.path, self.line, self.col)

    def format_text(self) -> str:
        out = f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out

    def to_json(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "hint": self.hint,
        }


@dataclass
class SourceFile:
    """A parsed module plus the raw text the comment-level checks need."""

    path: Path                 # absolute
    relpath: str               # relative to the project root, posix
    modname: str               # dotted module name ("repro.engine.cache")
    text: str
    lines: List[str]
    tree: ast.Module
    # line -> set of rule ids suppressed there ({"*"} = all rules)
    suppressions: Dict[int, set] = field(default_factory=dict)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


@dataclass
class Project:
    """All source files under analysis, keyed by relpath and modname."""

    root: Path
    files: Dict[str, SourceFile] = field(default_factory=dict)
    by_modname: Dict[str, SourceFile] = field(default_factory=dict)

    def add(self, sf: SourceFile) -> None:
        self.files[sf.relpath] = sf
        self.by_modname[sf.modname] = sf

    def iter_files(self) -> Iterable[SourceFile]:
        return self.files.values()


def _modname_for(path: Path, root: Path) -> str:
    """Dotted module name for *path*, stripping a leading ``src/`` layer."""
    try:
        rel = path.resolve().relative_to(root.resolve())
    except ValueError:
        rel = Path(path.name)
    parts = list(rel.with_suffix("").parts)
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) if parts else path.stem


def _parse_suppressions(lines: Sequence[str]) -> Dict[int, set]:
    """Map line numbers to suppressed rule ids.

    A trailing comment suppresses its own line; a standalone comment
    suppresses itself and the next non-comment line (so a multi-line
    explanation can sit between the directive and the statement).
    """
    out: Dict[int, set] = {}
    for i, raw in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(raw)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        if not rules:
            continue
        out.setdefault(i, set()).update(rules)
        if raw.lstrip().startswith("#"):  # standalone comment line
            j = i  # 0-based index of the line after the directive
            while j < len(lines) and lines[j].lstrip().startswith("#"):
                j += 1
            out.setdefault(j + 1, set()).update(rules)
    return out


def load_source(path: Path, root: Path) -> Optional[SourceFile]:
    """Parse one .py file; returns None on syntax errors (reported by caller)."""
    text = path.read_text(encoding="utf-8")
    try:
        tree = ast.parse(text, filename=str(path))
    except SyntaxError:
        return None
    try:
        relpath = path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        relpath = path.name
    lines = text.splitlines()
    return SourceFile(
        path=path,
        relpath=relpath,
        modname=_modname_for(path, root),
        text=text,
        lines=lines,
        tree=tree,
        suppressions=_parse_suppressions(lines),
    )


def load_project(paths: Sequence[str], root: Optional[str] = None) -> Project:
    """Load every .py file under *paths* (files or directories)."""
    root_path = Path(root) if root is not None else Path.cwd()
    project = Project(root=root_path)
    seen = set()
    for p in paths:
        base = Path(p)
        if base.is_dir():
            candidates = sorted(base.rglob("*.py"))
        else:
            candidates = [base]
        for cand in candidates:
            key = cand.resolve()
            if key in seen or not cand.suffix == ".py":
                continue
            seen.add(key)
            sf = load_source(cand, root_path)
            if sf is not None:
                project.add(sf)
    return project


def is_suppressed(sf: SourceFile, finding: Finding) -> bool:
    rules = sf.suppressions.get(finding.line)
    if not rules:
        return False
    return "*" in rules or "all" in rules or finding.rule in rules


# ---------------------------------------------------------------------------
# Baseline: fail CI only on NEW findings.
# ---------------------------------------------------------------------------

def save_baseline(findings: Sequence[Finding], path: str) -> None:
    payload = {
        "version": BASELINE_VERSION,
        "findings": [f.to_json() for f in sorted(findings, key=lambda f: f.key())],
    }
    Path(path).write_text(json.dumps(payload, indent=2) + "\n", encoding="utf-8")


def load_baseline(path: str) -> List[Finding]:
    payload = json.loads(Path(path).read_text(encoding="utf-8"))
    version = payload.get("version")
    if version != BASELINE_VERSION:
        raise ValueError(f"unsupported opslint baseline version: {version!r}")
    out = []
    for row in payload.get("findings", []):
        out.append(Finding(
            rule=row["rule"], path=row["path"], line=int(row["line"]),
            col=int(row.get("col", 0)), message=row.get("message", ""),
            hint=row.get("hint", ""),
        ))
    return out
