"""Setup-step analysis (OpSparse Fig. 2 step 1): n_prod per row, CR.

The paper computes ``n_prod`` per output row in the setup step and stores
it in the (reused) ``C.rpt`` array (§5.3).  ``n_prod[i] = sum_k |B_{k*}|``
over the column ids k of A's row i — a gather + segment-sum, no multiply.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSR


@jax.jit
def nprod_per_entry(A: CSR, B: CSR) -> jax.Array:
    """(capA,) int32 — |B row| for each stored entry of A (0 for padding)."""
    b_sizes = B.nnz_per_row()
    safe_col = jnp.minimum(A.col, B.nrows - 1)
    return jnp.where(A.entry_mask(), b_sizes[safe_col], 0).astype(jnp.int32)


@jax.jit
def nprod_into_rpt(A: CSR, B: CSR) -> jax.Array:
    """(M+1,) int32 buffer with ``[0:M] = n_prod per row`` and ``[M] = 0``.

    This IS the metadata-minimization trick of §5.3: the n_prod (and later
    n_nz) vectors live inside the storage that will become ``C.rpt``; the
    exclusive-sum that turns n_nz into row pointers runs in place.
    """
    per_entry = nprod_per_entry(A, B)
    rows = A.row_ids()  # padding rows -> M, dropped by the scatter
    buf = jnp.zeros(A.nrows + 1, dtype=jnp.int32)
    return buf.at[rows].add(per_entry, mode="drop")


@jax.jit
def total_nprod(A: CSR, B: CSR) -> jax.Array:
    return jnp.sum(nprod_per_entry(A, B))


def row_flops(A: CSR, B: CSR):
    """(M,) int64 HOST array: flop estimate per output row — 2 * n_prod
    (one multiply and one add per intermediate product).

    This is the load-balance weight for row-block partitioning (the
    SpGEMM-survey's key scaling lever): splitting A by *cumulative* row
    flops, rather than by row count, keeps skewed matrices' shards even.
    The doubling happens host-side in int64: on device (x64 disabled)
    ``2 * nprod`` wraps int32, and a wrapped weight silently degenerates
    the partition instead of erroring.  Callers are host-side anyway —
    this read IS the partitioner's one cold-call sync.
    """
    nprod = jax.device_get(nprod_into_rpt(A, B)[:A.nrows])
    return 2 * np.asarray(nprod, dtype=np.int64)


def compression_ratio(A: CSR, B: CSR, C: CSR) -> float:
    """Paper Eq. (3): total n_prod / nnz(C)."""
    npd = int(total_nprod(A, B))
    nnz = int(C.nnz())
    return npd / max(nnz, 1)


def exclusive_sum_in_place(buf: jax.Array) -> jax.Array:
    """(M+1,) counts-buffer -> row pointers, in place (cub ExclusiveSum
    analog; XLA reuses the donated buffer)."""
    return jnp.concatenate(
        [jnp.zeros(1, buf.dtype), jnp.cumsum(buf[:-1]).astype(buf.dtype)])
