"""Setup-step analysis (OpSparse Fig. 2 step 1): n_prod per row, CR —
plus the sampling nnz estimator behind ``plan_mode="estimate"``.

The paper computes ``n_prod`` per output row in the setup step and stores
it in the (reused) ``C.rpt`` array (§5.3).  ``n_prod[i] = sum_k |B_{k*}|``
over the column ids k of A's row i — a gather + segment-sum, no multiply.

The estimator (Ocean, arxiv 2604.19004) replaces the full symbolic pass
for cold plans: n_prod per row is exact and cheap, so only the
compression — nnz_i / nprod_i — needs sampling.  A small deterministic
row sample is measured *exactly* (per-row column union), the sampled
ratios give a [r_lo, r_hi] band, and every row's possible nnz range
feeds a range-histogram over the numeric bin ladder.

The ENTIRE estimator is host-side numpy over one fetch of the operand
index arrays: the point of ``plan_mode="estimate"`` is to skip kernel
compiles on the cold path, so the estimator must not introduce its own
(an early version measured the sample through the jitted esc symbolic
kernel and spent more on that compile than the exact sizing pass it was
replacing).  The index fetch is O(nnz) like the n_prod sync the exact
partitioner already pays; values are never touched.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSR


@jax.jit
def nprod_per_entry(A: CSR, B: CSR) -> jax.Array:
    """(capA,) int32 — |B row| for each stored entry of A (0 for padding)."""
    b_sizes = B.nnz_per_row()
    safe_col = jnp.minimum(A.col, B.nrows - 1)
    return jnp.where(A.entry_mask(), b_sizes[safe_col], 0).astype(jnp.int32)


@jax.jit
def nprod_into_rpt(A: CSR, B: CSR) -> jax.Array:
    """(M+1,) int32 buffer with ``[0:M] = n_prod per row`` and ``[M] = 0``.

    This IS the metadata-minimization trick of §5.3: the n_prod (and later
    n_nz) vectors live inside the storage that will become ``C.rpt``; the
    exclusive-sum that turns n_nz into row pointers runs in place.
    """
    per_entry = nprod_per_entry(A, B)
    rows = A.row_ids()  # padding rows -> M, dropped by the scatter
    buf = jnp.zeros(A.nrows + 1, dtype=jnp.int32)
    return buf.at[rows].add(per_entry, mode="drop")


@jax.jit
def total_nprod(A: CSR, B: CSR) -> jax.Array:
    return jnp.sum(nprod_per_entry(A, B))


def row_flops(A: CSR, B: CSR):
    """(M,) int64 HOST array: flop estimate per output row — 2 * n_prod
    (one multiply and one add per intermediate product).

    This is the load-balance weight for row-block partitioning (the
    SpGEMM-survey's key scaling lever): splitting A by *cumulative* row
    flops, rather than by row count, keeps skewed matrices' shards even.
    The doubling happens host-side in int64: on device (x64 disabled)
    ``2 * nprod`` wraps int32, and a wrapped weight silently degenerates
    the partition instead of erroring.  Callers are host-side anyway —
    this read IS the partitioner's one cold-call sync.
    """
    nprod = jax.device_get(nprod_into_rpt(A, B)[:A.nrows])
    return 2 * np.asarray(nprod, dtype=np.int64)


def compression_ratio(A: CSR, B: CSR, C: CSR) -> float:
    """Paper Eq. (3): total n_prod / nnz(C)."""
    npd = int(total_nprod(A, B))
    nnz = int(C.nnz())
    return npd / max(nnz, 1)


def exclusive_sum_in_place(buf: jax.Array) -> jax.Array:
    """(M+1,) counts-buffer -> row pointers, in place (cub ExclusiveSum
    analog; XLA reuses the donated buffer)."""
    return jnp.concatenate(
        [jnp.zeros(1, buf.dtype), jnp.cumsum(buf[:-1]).astype(buf.dtype)])


# ---------------------------------------------------------------------------
# Sampling nnz/flop estimator (plan_mode="estimate").
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ResultEstimate:
    """Host-side sizing prediction for C = A·B, from n_prod + a row sample.

    Everything the engine needs to specialize a plan without the full
    symbolic pass.  ``sym_*`` fields are EXACT (n_prod is exact);
    ``num_*`` / ``total_nnz_high`` are conservative band-derived upper
    estimates whose misses the overflow-grow retrace path corrects.
    """

    sym_counts: Tuple[int, ...]    # exact rows per sym rung (+fallback last)
    sym_fall_prod: int             # exact Σ n_prod over sym-fallback rows
    num_counts: Tuple[int, ...]    # band upper-count per num rung (+fallback)
    num_fall_prod: int             # band-high Σ n_prod over possible num-fallback rows
    total_nprod: int               # exact Σ n_prod (int64-safe python int)
    total_nnz_high: int            # band-high Σ nnz  (nnz-capacity sizing)
    r_lo: float                    # sampled compression-ratio band
    r_hi: float
    sampled_rows: int              # rows actually measured (nprod > 0)


def _classify_np(x: np.ndarray, upper: Tuple[int, ...]) -> np.ndarray:
    """Host mirror of ``binning.classify``: rung index per size, with
    sizes above ``upper[-1]`` landing on the fallback rung ``len(upper)``."""
    return np.searchsorted(np.asarray(upper, dtype=np.int64), x, side="left")


def sample_rows_for_estimate(nprod: np.ndarray, n_sample: int) -> np.ndarray:
    """Deterministic stratified sample of rows with ``nprod > 0``.

    Top-k heaviest rows (they dominate both flops and the nnz total, and
    the tail of the ratio distribution lives there) plus a stride across
    the remaining size-sorted rows so every size stratum is represented.
    Returns row ids, possibly fewer than ``n_sample`` (never more).
    """
    nonzero = np.flatnonzero(nprod > 0)
    if nonzero.size <= n_sample:
        return nonzero.astype(np.int64)
    order = nonzero[np.argsort(nprod[nonzero], kind="stable")][::-1]
    k = max(n_sample // 4, 1)
    rest = order[k:]
    n_strided = n_sample - k
    stride_idx = (np.arange(n_strided, dtype=np.int64)
                  * rest.size // n_strided)
    return np.concatenate([order[:k], rest[stride_idx]])


def host_index(M: CSR) -> Tuple[np.ndarray, np.ndarray]:
    """(rpt, col) int64 HOST copies of a CSR's index arrays (one fetch,
    values untouched)."""
    return (np.asarray(jax.device_get(M.rpt), dtype=np.int64),
            np.asarray(jax.device_get(M.col), dtype=np.int64))


def host_nprod(a_rpt: np.ndarray, a_col: np.ndarray,
               b_rpt: np.ndarray) -> np.ndarray:
    """(M,) int64 n_prod per row from host index arrays — the same
    quantity as ``nprod_into_rpt`` without compiling anything.

    Padding entries beyond ``a_rpt[-1]`` (and any out-of-range column)
    contribute 0, mirroring the device kernel's entry mask.  The per-row
    sum is a cumulative-sum difference at the row pointers, so the whole
    thing is three vectorized passes over the entry array.
    """
    nb = b_rpt.shape[0] - 1
    if nb <= 0:
        return np.zeros(a_rpt.shape[0] - 1, dtype=np.int64)
    b_len = b_rpt[1:] - b_rpt[:-1]
    in_range = (a_col >= 0) & (a_col < nb)
    contrib = np.where(in_range, b_len[np.clip(a_col, 0, nb - 1)], 0)
    cs = np.concatenate([np.zeros(1, np.int64),
                         np.cumsum(contrib, dtype=np.int64)])
    return cs[a_rpt[1:]] - cs[a_rpt[:-1]]


def measure_sample_nnz(rows: np.ndarray,
                       a_rpt: np.ndarray, a_col: np.ndarray,
                       b_rpt: np.ndarray, b_col: np.ndarray) -> np.ndarray:
    """EXACT structural nnz of the sampled C rows — host column union.

    The sample is tiny (<= ``est_sample_rows``), so per-row unions over
    the referenced B rows cost microseconds of numpy and, crucially,
    compile NOTHING — this replaces an earlier device-side measurement
    whose gather+symbolic jit compiles dwarfed the savings.
    """
    nb = b_rpt.shape[0] - 1
    out = np.zeros(rows.size, dtype=np.int64)
    for i, r in enumerate(rows):
        ks = a_col[a_rpt[r]:a_rpt[r + 1]]
        ks = ks[(ks >= 0) & (ks < nb)]
        if ks.size == 0:
            continue
        cols = np.concatenate([b_col[b_rpt[k]:b_rpt[k + 1]] for k in ks])
        out[i] = np.unique(cols).size
    return out


def derive_estimate(nprod: np.ndarray,
                    sampled_rows: np.ndarray,
                    sampled_nnz: np.ndarray, *,
                    sym_upper: Tuple[int, ...],
                    num_upper: Tuple[int, ...],
                    ncols: int,
                    quantile: float = 0.9,
                    headroom: float = 1.5) -> ResultEstimate:
    """Pure host derivation: sampled ratios -> per-rung counts + totals.

    All math in int64 numpy / python int so near-2^31 products cannot
    wrap (the same discipline as ``row_flops``).

    The numeric-rung counts are a *range histogram*: each row's nnz can
    land anywhere in its band [ceil(nprod·r_lo), min(ceil(nprod·r_hi),
    nprod, ncols)], so the row counts toward EVERY rung the band
    intersects (a difference array keeps this O(M + rungs)).  Per-rung
    counts are therefore upper bounds — the right direction for pow-2
    bucket sizing — while rows whose band-high crosses the fallback
    threshold contribute their full n_prod to the fallback capacity.
    """
    nprod = np.asarray(nprod, dtype=np.int64)
    m = nprod.shape[0]
    total_nprod = int(np.sum(nprod, dtype=np.int64))

    # Exact symbolic side: binning is on n_prod, which we hold exactly.
    sym_bin = _classify_np(nprod, sym_upper)
    sym_counts = np.bincount(sym_bin, minlength=len(sym_upper) + 1)
    sym_fall_prod = int(np.sum(nprod[sym_bin == len(sym_upper)],
                               dtype=np.int64))

    # Ratio band from the sample (rows with nprod == 0 carry no signal
    # and are never sampled; an empty sample means an all-empty matrix).
    sampled_rows = np.asarray(sampled_rows, dtype=np.int64)
    sampled_nnz = np.asarray(sampled_nnz, dtype=np.int64)
    if sampled_rows.size:
        ratios = sampled_nnz / np.maximum(nprod[sampled_rows], 1)
        r_hi = float(min(np.quantile(ratios, quantile) * headroom, 1.0))
        r_hi = max(r_hi, float(np.max(ratios)) if ratios.size else 1.0)
        r_hi = min(r_hi, 1.0)
        r_lo = float(min(np.min(ratios) * 0.5, r_hi))
    else:
        r_lo, r_hi = 1.0, 1.0

    # Per-row nnz bands (nnz >= 1 whenever nprod >= 1; <= min(nprod, N)).
    pos = nprod > 0
    hi = np.minimum(np.minimum(
        np.ceil(nprod * r_hi).astype(np.int64), nprod), int(ncols))
    hi = np.where(pos, np.maximum(hi, 1), 0)
    lo = np.floor(nprod * r_lo).astype(np.int64)
    lo = np.where(pos, np.clip(lo, 1, hi), 0)
    total_nnz_high = int(np.sum(hi, dtype=np.int64))

    # Range histogram over the numeric ladder via a difference array.
    n_num = len(num_upper) + 1
    lo_bin = _classify_np(lo, num_upper)
    hi_bin = _classify_np(hi, num_upper)
    diff = np.zeros(n_num + 1, dtype=np.int64)
    np.add.at(diff, lo_bin, 1)
    np.add.at(diff, hi_bin + 1, -1)
    num_counts = np.cumsum(diff)[:n_num]
    num_fall_prod = int(np.sum(nprod[hi_bin == len(num_upper)],
                               dtype=np.int64))

    return ResultEstimate(
        sym_counts=tuple(int(c) for c in sym_counts),
        sym_fall_prod=sym_fall_prod,
        num_counts=tuple(int(c) for c in num_counts),
        num_fall_prod=num_fall_prod,
        total_nprod=total_nprod,
        total_nnz_high=total_nnz_high,
        r_lo=r_lo, r_hi=r_hi,
        sampled_rows=int(sampled_rows.size),
    )


def estimate_result(A: CSR, B: CSR, *,
                    sym_upper: Tuple[int, ...],
                    num_upper: Tuple[int, ...],
                    n_sample: int = 64,
                    quantile: float = 0.9,
                    headroom: float = 1.5,
                    nprod: np.ndarray | None = None) -> ResultEstimate:
    """Size C = A·B from n_prod + an exactly-measured row sample.

    One host fetch of each operand's index arrays, then pure numpy: no
    kernel runs, no jit compiles — versus the exact path's full symbolic
    pass (and its per-bucket kernel compiles) over every intermediate
    product.
    """
    a_rpt, a_col = host_index(A)
    b_rpt, b_col = host_index(B)
    if nprod is None:
        nprod = host_nprod(a_rpt, a_col, b_rpt)
    rows = sample_rows_for_estimate(nprod, n_sample)
    nnz = measure_sample_nnz(rows, a_rpt, a_col, b_rpt, b_col)
    return derive_estimate(
        nprod, rows, nnz, sym_upper=sym_upper, num_upper=num_upper,
        ncols=B.ncols, quantile=quantile, headroom=headroom)
