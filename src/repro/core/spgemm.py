"""The OpSparse two-phase SpGEMM API (paper Fig. 2).

Six steps, faithful to the paper's flow:

  step1 SETUP      n_prod per row, written into the C.rpt storage (§5.3);
                   workspace planned (ONE fused metadata buffer).
  step2 SYM-BIN    binning on n_prod (sym ladder, default 1.2x ranges).
  step3 SYMBOLIC   n_nz per row via per-bin hash kernels (Pallas) or the
                   ESC accumulator; result overwrites the same rpt buffer.
  step4 ALLOC      total n_nz -> host; rpt = in-place exclusive-sum; C.col
                   / C.val capacity chosen (pow-2 bucket: the static-shape
                   analog of cudaMalloc, bucketing bounds recompiles).
  step5 NUM-BIN    binning on n_nz (num ladder, default 2x ranges).
  step6 NUMERIC    fill C.col/C.val, rows sorted by column.

The flow itself lives in ``repro.engine.executor``; ``spgemm()`` is a thin
plan-then-execute wrapper over the process-wide execution-plan engine.
Repeat calls whose operands land in the same shape bucket reuse a cached
plan and its jitted executable (the recompile analog of §5.4's
cudaMalloc/exec overlap) — same results, no re-tracing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

import jax

from .binning import Binning
from .binning_ranges import BinLadder, numeric_ladder, symbolic_ladder
from .csr import CSR
from .workspace import next_bucket  # canonical home (re-exported for API compat)


# ``SpgemmConfig.shards`` sentinel: let the engine's adaptive policy pick
# the shard count from stream telemetry (``repro.engine.autotune``)
# instead of a static knob.  0 (not None) keeps the config JSON-trivially
# serializable and totally ordered for cache keys.
AUTO_SHARDS = 0


@dataclasses.dataclass(frozen=True)
class SpgemmConfig:
    method: str = "esc"              # "esc" | "hash"
    sym_multiplier: float = 1.2      # paper's sym_1.2x
    num_multiplier: float = 2.0      # paper's num_2x
    vmem_extended: bool = False      # TPU ladder extension (DESIGN.md §5)
    hash_single_access: bool = True  # §5.2 single-access vs multi-access
    fuse_esc: bool = False           # beyond-paper single-expansion ESC
    # Hash default since the fusion soaked (ISSUE 4 -> 5): one table build
    # per row.  The two-pass form remains the cold-path / parity oracle
    # and the automatic fallback whenever ``admits_fused`` fails.
    fuse_numeric: bool = True        # hash: one-build symbolic->numeric fusion
    row_packing: bool = False        # hash: pack small rows per VMEM tile
    # Pallas interpret mode: None = auto-detect (interpret everywhere but a
    # real TPU backend, so the same code runs compiled on hardware without
    # callers threading the flag; see repro.kernels.resolve_interpret).
    interpret: Optional[bool] = None
    timing: bool = False             # per-step wall-clock (benchmarks)
    shards: int = 1                  # row-block shards of A (engine fan-out;
                                     # AUTO_SHARDS = telemetry-chosen)
    # Cold-path planning mode.  "exact" = the paper's full symbolic pass
    # sizes every bucket before the first execution; "estimate" = the
    # Ocean-style sampled nnz estimator predicts the buckets and the cold
    # call jumps straight to a specialized executable, with the
    # overflow-grow retrace as the correctness safety net.  (Warm starts
    # via PlanCache.load are orthogonal and work with either.)
    plan_mode: str = "exact"         # "exact" | "estimate"

    def ladders(self) -> tuple[BinLadder, BinLadder]:
        return (symbolic_ladder(self.sym_multiplier, vmem_extended=self.vmem_extended),
                numeric_ladder(self.num_multiplier, vmem_extended=self.vmem_extended))


@dataclasses.dataclass
class SpgemmResult:
    C: CSR
    total_nprod: int
    total_nnz: int
    sym_binning: Optional[Binning]
    num_binning: Optional[Binning]
    timings: Dict[str, float]

    @property
    def compression_ratio(self) -> float:
        return self.total_nprod / max(self.total_nnz, 1)


def spgemm(A: CSR, B: CSR, config: SpgemmConfig = SpgemmConfig(), *,
           shards: Union[int, str, None] = None) -> SpgemmResult:
    """C = A · B in CSR, two-phase, binned, statically bucketed.

    Executed through the shared :class:`repro.engine.SpgemmEngine`: the
    call is planned against the operands' shape-bucket signatures, and
    repeat signatures skip straight to a cached jitted executable.

    ``shards=N`` partitions A into N flop-balanced row blocks and fans
    the product out into per-shard sub-dispatches (one plan, N shards);
    results are merged back into one CSR with identical nnz/structure.
    ``shards="auto"`` (or ``AUTO_SHARDS``) lets the engine's adaptive
    policy pick N per plan from observed flop totals instead.
    """
    assert A.ncols == B.nrows, (A.shape, B.shape)
    if shards is not None:
        shards = AUTO_SHARDS if shards == "auto" else int(shards)
        config = dataclasses.replace(config, shards=shards)
    # Imported lazily: core is the engine's substrate, so the dependency
    # points engine -> core at module-load time and core -> engine only here.
    from repro.engine.executor import default_engine
    return default_engine().execute(A, B, config)


def spgemm_reference(A: CSR, B: CSR) -> jax.Array:
    """Dense oracle (tests): to_dense(A) @ to_dense(B)."""
    return A.to_dense() @ B.to_dense()
