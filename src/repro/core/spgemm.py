"""The OpSparse two-phase SpGEMM orchestrator (paper Fig. 2).

Six steps, faithful to the paper's flow:

  step1 SETUP      n_prod per row, written into the C.rpt storage (§5.3);
                   workspace planned (ONE fused metadata buffer).
  step2 SYM-BIN    binning on n_prod (sym ladder, default 1.2x ranges).
  step3 SYMBOLIC   n_nz per row via per-bin hash kernels (Pallas) or the
                   ESC accumulator; result overwrites the same rpt buffer.
  step4 ALLOC      total n_nz -> host; rpt = in-place exclusive-sum; C.col
                   / C.val capacity chosen (pow-2 bucket: the static-shape
                   analog of cudaMalloc, bucketing bounds recompiles).
  step5 NUM-BIN    binning on n_nz (num ladder, default 2x ranges).
  step6 NUMERIC    fill C.col/C.val, rows sorted by column.

Host/device overlap (§5.4–§5.5 adaptation): every step is dispatched
asynchronously; the only host syncs are the two the paper itself has (the
total-n_prod / total-n_nz reads that size the next launch), plus the Alg-3
fast-path check.  Between dispatch and sync the host plans buckets and
workspaces — the analog of overlapping cudaMalloc with kernel execution.
Large-row fallback rows (beyond the top hash rung) are computed with the
ESC accumulator — the analog of the paper's global-memory hash kernels.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from . import esc
from .analysis import nprod_into_rpt, exclusive_sum_in_place
from .binning import Binning, bin_rows_for_ladder
from .binning_ranges import BinLadder, numeric_ladder, symbolic_ladder
from .csr import CSR


@dataclasses.dataclass(frozen=True)
class SpgemmConfig:
    method: str = "esc"              # "esc" | "hash"
    sym_multiplier: float = 1.2      # paper's sym_1.2x
    num_multiplier: float = 2.0      # paper's num_2x
    vmem_extended: bool = False      # TPU ladder extension (DESIGN.md §5)
    hash_single_access: bool = True  # §5.2 single-access vs multi-access
    fuse_esc: bool = False           # beyond-paper single-expansion ESC
    interpret: bool = True           # Pallas interpret mode (CPU container)
    timing: bool = False             # per-step wall-clock (benchmarks)

    def ladders(self) -> tuple[BinLadder, BinLadder]:
        return (symbolic_ladder(self.sym_multiplier, vmem_extended=self.vmem_extended),
                numeric_ladder(self.num_multiplier, vmem_extended=self.vmem_extended))


@dataclasses.dataclass
class SpgemmResult:
    C: CSR
    total_nprod: int
    total_nnz: int
    sym_binning: Optional[Binning]
    num_binning: Optional[Binning]
    timings: Dict[str, float]

    @property
    def compression_ratio(self) -> float:
        return self.total_nprod / max(self.total_nnz, 1)


def next_bucket(n: int, *, minimum: int = 16) -> int:
    """Pow-2 shape bucket — bounds both padding waste (<2x) and the number
    of distinct compiled executables (the recompile<->cudaMalloc analog)."""
    b = minimum
    while b < n:
        b <<= 1
    return b


_exclusive_sum = jax.jit(exclusive_sum_in_place, donate_argnums=0)


class _StepTimer:
    def __init__(self, enabled: bool):
        self.enabled = enabled
        self.timings: Dict[str, float] = {}

    def measure(self, name: str, value):
        """Block on `value` and charge the elapsed time to `name`."""
        if self.enabled:
            t0 = time.perf_counter()
            jax.block_until_ready(value)
            self.timings[name] = self.timings.get(name, 0.0) + (
                time.perf_counter() - t0)
        return value


def spgemm(A: CSR, B: CSR, config: SpgemmConfig = SpgemmConfig()) -> SpgemmResult:
    """C = A · B in CSR, two-phase, binned, statically bucketed."""
    assert A.ncols == B.nrows, (A.shape, B.shape)
    m = A.nrows
    sym_ladder, num_ladder = config.ladders()
    timer = _StepTimer(config.timing)

    # ---- step1: setup -----------------------------------------------------
    rpt_buf = nprod_into_rpt(A, B)               # n_prod lives in C.rpt (§5.3)
    timer.measure("setup", rpt_buf)
    nprod = rpt_buf[:m]
    total_nprod = int(jnp.sum(nprod))            # host sync #1 (sizes launches)

    # ---- step2: symbolic binning -------------------------------------------
    sym_binning = bin_rows_for_ladder(nprod, sym_ladder)
    timer.measure("symbolic_binning", sym_binning.bins)

    prod_capacity = next_bucket(max(total_nprod, 1))

    # ---- step3: symbolic ----------------------------------------------------
    if config.method == "hash":
        from repro.kernels import spgemm_hash
        nnz_buf = spgemm_hash.symbolic_binned(
            A, B, sym_binning, sym_ladder,
            prod_capacity=prod_capacity,
            single_access=config.hash_single_access,
            interpret=config.interpret)
    else:
        nnz_buf = esc.symbolic(A, B, prod_capacity=prod_capacity)
    timer.measure("symbolic", nnz_buf)

    # ---- step4: alloc -------------------------------------------------------
    nnz = nnz_buf[:m]
    # Numeric binning is dispatched BEFORE the host reads total_nnz: the
    # launch-early / allocate-later ordering of §5.4.
    num_binning = bin_rows_for_ladder(nnz, num_ladder)
    total_nnz = int(jnp.sum(nnz))                # host sync #2 (alloc C)
    nnz_capacity = next_bucket(max(total_nnz, 1))
    rpt = _exclusive_sum(nnz_buf)                # in-place on the rpt buffer
    timer.measure("alloc", rpt)
    timer.measure("numeric_binning", num_binning.bins)

    # ---- step6: numeric -----------------------------------------------------
    if config.method == "hash":
        from repro.kernels import spgemm_hash
        C = spgemm_hash.numeric_binned(
            A, B, rpt, num_binning, num_ladder,
            prod_capacity=prod_capacity, nnz_capacity=nnz_capacity,
            single_access=config.hash_single_access,
            interpret=config.interpret)
    elif config.fuse_esc:
        C = esc.spgemm_fused(A, B, prod_capacity=prod_capacity,
                             nnz_capacity=nnz_capacity)
    else:
        C = esc.numeric(A, B, rpt, prod_capacity=prod_capacity,
                        nnz_capacity=nnz_capacity)
    timer.measure("numeric", C.val)

    return SpgemmResult(
        C=C, total_nprod=total_nprod, total_nnz=total_nnz,
        sym_binning=sym_binning, num_binning=num_binning,
        timings=timer.timings)


def spgemm_reference(A: CSR, B: CSR) -> jax.Array:
    """Dense oracle (tests): to_dense(A) @ to_dense(B)."""
    return A.to_dense() @ B.to_dense()
