"""Deterministic fault injection for the engine and serving front-end.

The paper's verify-and-grow recovery (and the progressive re-allocation
discipline it inherits from the Liu–Vinter framework) is only trustworthy
if every recovery rung actually runs in CI — but real memory pressure,
estimator misses, and device failures are non-deterministic and slow to
provoke.  A :class:`FaultPlan` makes them cheap and exactly repeatable:
a seedable schedule of injections at *named sites* the engine consults on
its hot path, threaded through constructors the same way ``telemetry=``
is (duck-typed keyword, zero overhead when absent).

Sites (:data:`SITES`):

  ``lease_denial``     the arena/engine workspace acquisition behaves as
                       if the governor cap were binding (returns no
                       lease) — walks the real degradation ladder, up to
                       :class:`~repro.core.workspace.ArenaPressureError`
                       backpressure, without real pressure.
  ``verify_overflow``  the finalize verify treats an admitted run as
                       overflowed — exercises the overflow-grow redo
                       (bitwise via the steps oracle) on demand.
  ``executor_raise``   dispatch raises :class:`InjectedFault` — the
                       non-transient (or, with ``transient=True``,
                       transient) failure a retry classifier must
                       distinguish from pressure.
  ``slow_dispatch``    dispatch stalls ``delay_s`` of host wall-clock —
                       deadline-budget expiry on demand.

Scheduling is by *visit index*: each time the engine consults a site the
plan's per-site visit counter advances, and a :class:`FaultSpec` fires
when the index is in its ``at`` tuple (or, for soak-style chaos runs,
with seeded ``probability`` per visit).  Same specs + same seed + same
request sequence => the same injections, which is what lets the chaos
gate assert bitwise parity against a fault-free run.

This module imports nothing from the engine (mirroring ``telemetry.py``)
so executor/arena/service can all depend on it freely.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Dict, Iterable, Optional, Tuple

SITES: Tuple[str, ...] = ("lease_denial", "verify_overflow",
                          "executor_raise", "slow_dispatch")


class InjectedFault(RuntimeError):
    """An injected ``executor_raise`` fault.  ``transient`` is the retry
    classification the injector chose: transient faults model recoverable
    blips (a retry should succeed), non-transient ones model poisoned
    requests (a retry must NOT fire)."""

    def __init__(self, message: str, *, site: str = "executor_raise",
                 transient: bool = False):
        super().__init__(message)
        self.site = site
        self.transient = transient


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection rule at one site.

    ``at``           visit indices (0-based, per site) that fire; ``None``
                     means fire by ``probability`` instead.
    ``probability``  per-visit seeded coin when ``at`` is None.
    ``count``        max injections this spec contributes (None = all).
    ``delay_s``      host stall for ``slow_dispatch`` injections.
    ``transient``    classification of ``executor_raise`` injections.
    ``message``      override for the raised/injected description.
    """

    site: str
    at: Optional[Tuple[int, ...]] = None
    probability: float = 0.0
    count: Optional[int] = None
    delay_s: float = 0.0
    transient: bool = False
    message: str = ""

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"known sites: {SITES}")
        if self.at is not None:
            object.__setattr__(self, "at", tuple(int(i) for i in self.at))


class FaultPlan:
    """A deterministic, seedable schedule of fault injections.

    Thread-safe (the engine consults sites from drain loops and service
    worker threads concurrently); ``enabled`` is False for an empty plan
    so the engine's hot-path guard costs one attribute read.

    ``visits``/``injected`` are per-site counters; :meth:`snapshot`
    returns both (the chaos gate records them in its trajectory entry).
    """

    def __init__(self, specs: Iterable[FaultSpec] = (), *, seed: int = 0):
        self.specs = tuple(specs)
        for spec in self.specs:
            if not isinstance(spec, FaultSpec):
                raise TypeError(f"expected FaultSpec, got {type(spec)}")
        self.seed = int(seed)
        self.enabled = bool(self.specs)
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self._remaining = [spec.count for spec in self.specs]
        self.visits: Dict[str, int] = {site: 0 for site in SITES}
        self.injected: Dict[str, int] = {site: 0 for site in SITES}

    # -- scheduling ---------------------------------------------------------
    def fire(self, site: str, *, uid: Optional[int] = None
             ) -> Optional[FaultSpec]:
        """Consult one site: advance its visit counter and return the
        spec that fires at this visit (or None).  At most one spec fires
        per visit (first match in declaration order)."""
        if not self.enabled:
            return None
        with self._lock:
            v = self.visits[site]
            self.visits[site] = v + 1
            for i, spec in enumerate(self.specs):
                if spec.site != site:
                    continue
                rem = self._remaining[i]
                if rem is not None and rem <= 0:
                    continue
                if spec.at is not None:
                    hit = v in spec.at
                else:
                    hit = (spec.probability > 0.0
                           and self._rng.random() < spec.probability)
                if hit:
                    if rem is not None:
                        self._remaining[i] = rem - 1
                    self.injected[site] += 1
                    return spec
            return None

    # -- convenience actions (the engine's site shims) ----------------------
    def maybe_raise(self, site: str = "executor_raise", *,
                    uid: Optional[int] = None) -> None:
        """Consult ``site`` and raise :class:`InjectedFault` on a hit."""
        spec = self.fire(site, uid=uid)
        if spec is not None:
            raise InjectedFault(
                spec.message or f"injected fault at {site} (uid={uid})",
                site=site, transient=spec.transient)

    def maybe_sleep(self, site: str = "slow_dispatch", *,
                    uid: Optional[int] = None) -> float:
        """Consult ``site``; stall ``delay_s`` on a hit.  Returns the
        stall applied (0.0 = no injection)."""
        spec = self.fire(site, uid=uid)
        if spec is None or spec.delay_s <= 0:
            return 0.0
        time.sleep(spec.delay_s)
        return spec.delay_s

    # -- introspection ------------------------------------------------------
    @property
    def total_injected(self) -> int:
        with self._lock:
            return sum(self.injected.values())

    def snapshot(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {"visits": dict(self.visits),
                    "injected": dict(self.injected)}


# The disabled default every constructor resolves to: consulting it is a
# single attribute read (``enabled`` False short-circuits fire()).
NULL_FAULTS = FaultPlan()


def resolve_faults(arg: Optional["FaultPlan"]) -> "FaultPlan":
    """Constructor sugar mirroring ``telemetry.resolve_telemetry``:
    ``None`` -> the shared disabled plan, a :class:`FaultPlan` -> itself."""
    if arg is None:
        return NULL_FAULTS
    if not isinstance(arg, FaultPlan):
        raise TypeError(f"faults= expects FaultPlan or None, got {type(arg)}")
    return arg
