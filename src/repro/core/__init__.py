"""OpSparse core: two-phase, binned, row-wise SpGEMM in JAX.

Public API:
  CSR, random_csr            — sparse container + synthetic generator
  spgemm, SpgemmConfig       — the paper's two-phase pipeline (Fig. 2)
  bin_rows_for_ladder        — two-pass binning (§5.1, also the MoE router)
  symbolic_ladder/numeric_ladder — bin ladders + range selection (§5.7)
"""
from .csr import CSR, random_csr
from .binning import Binning, bin_rows, bin_rows_for_ladder, bin_rows_identity, classify
from .binning_ranges import (BinLadder, make_ladder, numeric_ladder,
                             symbolic_ladder, SYMBOLIC_SWEEP, NUMERIC_SWEEP)
from .analysis import (compression_ratio, exclusive_sum_in_place,
                       nprod_into_rpt, nprod_per_entry, total_nprod)
from .spgemm import SpgemmConfig, SpgemmResult, next_bucket, spgemm, spgemm_reference
from .faults import FaultPlan, FaultSpec, InjectedFault
from . import esc

__all__ = [
    "CSR", "random_csr", "Binning", "bin_rows", "bin_rows_for_ladder",
    "bin_rows_identity", "classify", "BinLadder", "make_ladder",
    "numeric_ladder", "symbolic_ladder", "SYMBOLIC_SWEEP", "NUMERIC_SWEEP",
    "compression_ratio", "exclusive_sum_in_place", "nprod_into_rpt",
    "nprod_per_entry", "total_nprod", "SpgemmConfig", "SpgemmResult",
    "next_bucket", "spgemm", "spgemm_reference", "esc",
    "FaultPlan", "FaultSpec", "InjectedFault",
]
