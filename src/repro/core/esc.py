"""ESC (expand–sort–compress) accumulator — the TPU-idiomatic / fallback path.

The paper's accumulator is a per-row shared-memory hash table; rows too big
for the largest table spill to a *global-memory* hash table (symbolic
kernel8 / numeric kernel7).  On TPU, scalar hash probing underuses the VPU,
and the natural HBM-resident accumulator is a **sorted reduction**: expand
all intermediate products, sort by (row, col), and segment-reduce
duplicates.  This module implements that path fully vectorized in jnp — it
serves as

  * the production accumulator on flat/vector hardware,
  * the fallback ("global memory") rung of the hash ladder, and
  * the oracle the Pallas hash kernels are validated against.

Shapes are static: the expansion size is a host-chosen bucket
``prod_capacity >= total_nprod`` (pow-2 bucketing, see ``spgemm.py``);
padding products carry row id M / col id N and sort to the end.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .csr import CSR
from .analysis import nprod_per_entry


@partial(jax.jit, static_argnames=("prod_capacity", "with_values"))
def expand_products(A: CSR, B: CSR, *, prod_capacity: int,
                    with_values: bool = True):
    """Enumerate all intermediate products of C = A·B, row-major.

    Returns (rows, cols, vals, valid):
      rows/cols: (prod_capacity,) int32; padding = (M, N).
      vals:      (prod_capacity,) or None when ``with_values=False`` —
                 the symbolic phase avoids the multiply, like the paper.
      valid:     (prod_capacity,) bool.

    Construction: per-A-entry product counts -> exclusive offsets; each
    product slot t finds its A entry by searchsorted, its B entry by
    ``t - offset[e]``.  Everything is a gather; no data-dependent shapes.
    """
    m, n = A.nrows, B.ncols
    per_entry = nprod_per_entry(A, B)                       # (capA,)
    offsets = jnp.concatenate(
        [jnp.zeros(1, jnp.int32),
         jnp.cumsum(per_entry)[:-1].astype(jnp.int32)])     # (capA,)
    total = jnp.sum(per_entry)

    t = jnp.arange(prod_capacity, dtype=jnp.int32)
    valid = t < total
    # A entry owning product slot t: last e with offsets[e] <= t.
    e = jnp.searchsorted(offsets, t, side="right").astype(jnp.int32) - 1
    e = jnp.clip(e, 0, max(A.capacity - 1, 0))
    j = t - offsets[e]

    a_col = jnp.minimum(A.col[e], B.nrows - 1)
    b_idx = jnp.minimum(B.rpt[a_col] + j, max(B.capacity - 1, 0))

    a_rows = A.row_ids()                                    # (capA,)
    rows = jnp.where(valid, a_rows[e], m).astype(jnp.int32)
    cols = jnp.where(valid, B.col[b_idx], n).astype(jnp.int32)
    vals = None
    if with_values:
        vals = jnp.where(valid, A.val[e] * B.val[b_idx], 0)
    return rows, cols, vals, valid


def _sort_products(rows, cols, vals):
    """Stable (row, col) sort.  Two-key lexsort avoids 64-bit keys (the
    fused key row*N+col overflows int32 for the paper's large matrices)."""
    order = jnp.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    vals = None if vals is None else vals[order]
    return rows, cols, vals


@partial(jax.jit, static_argnames=("prod_capacity",))
def symbolic(A: CSR, B: CSR, *, prod_capacity: int) -> jax.Array:
    """Symbolic phase: (M+1,) buffer with n_nz per row in [0:M] (rpt reuse).

    No value multiply — mirrors the paper's symbolic step.
    """
    rows, cols, _, valid = expand_products(
        A, B, prod_capacity=prod_capacity, with_values=False)
    rows, cols, _ = _sort_products(rows, cols, None)
    prev_rows = jnp.concatenate([jnp.full((1,), -1, jnp.int32), rows[:-1]])
    prev_cols = jnp.concatenate([jnp.full((1,), -1, jnp.int32), cols[:-1]])
    is_new = (rows != prev_rows) | (cols != prev_cols)
    is_real = rows < A.nrows
    buf = jnp.zeros(A.nrows + 1, dtype=jnp.int32)
    return buf.at[rows].add((is_new & is_real).astype(jnp.int32), mode="drop")


@partial(jax.jit, static_argnames=("prod_capacity", "nnz_capacity"))
def numeric(A: CSR, B: CSR, rpt: jax.Array, *, prod_capacity: int,
            nnz_capacity: int) -> CSR:
    """Numeric phase: fill C.col / C.val given the symbolic-phase ``rpt``.

    Output rows are sorted by column id (the paper's numeric kernels sort
    after condensing; the global (row, col) sort gives this for free).
    """
    m, n = A.nrows, B.ncols
    rows, cols, vals, valid = expand_products(
        A, B, prod_capacity=prod_capacity, with_values=True)
    rows, cols, vals = _sort_products(rows, cols, vals)
    prev_rows = jnp.concatenate([jnp.full((1,), -1, jnp.int32), rows[:-1]])
    prev_cols = jnp.concatenate([jnp.full((1,), -1, jnp.int32), cols[:-1]])
    is_real = rows < m
    is_new = ((rows != prev_rows) | (cols != prev_cols)) & is_real
    # Output slot of each product = (#unique keys before it) - 1; products
    # of the same (row, col) share the slot and accumulate.
    out_idx = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    out_idx = jnp.where(is_real, out_idx, nnz_capacity)  # drop padding
    col_out = jnp.zeros(nnz_capacity, jnp.int32).at[out_idx].max(
        jnp.where(is_real, cols, 0), mode="drop")
    val_out = jnp.zeros(nnz_capacity, vals.dtype).at[out_idx].add(
        jnp.where(is_real, vals, 0), mode="drop")
    return CSR(rpt=rpt, col=col_out, val=val_out, shape=(m, n))


@partial(jax.jit, static_argnames=("prod_capacity", "nnz_capacity"))
def spgemm_fused(A: CSR, B: CSR, *, prod_capacity: int,
                 nnz_capacity: int) -> CSR:
    """One-pass ESC SpGEMM (expand once, derive rpt AND values).

    Beyond-paper optimization for the sorted accumulator: the symbolic and
    numeric phases share one expansion+sort when the nnz bucket is already
    known (steady-state shapes), halving HBM traffic.  Falls back to the
    faithful two-phase flow in ``spgemm.py`` when capacities are unknown.
    """
    m, n = A.nrows, B.ncols
    rows, cols, vals, _ = expand_products(
        A, B, prod_capacity=prod_capacity, with_values=True)
    rows, cols, vals = _sort_products(rows, cols, vals)
    prev_rows = jnp.concatenate([jnp.full((1,), -1, jnp.int32), rows[:-1]])
    prev_cols = jnp.concatenate([jnp.full((1,), -1, jnp.int32), cols[:-1]])
    is_real = rows < m
    is_new = ((rows != prev_rows) | (cols != prev_cols)) & is_real
    nnz_buf = jnp.zeros(m + 1, jnp.int32).at[rows].add(
        is_new.astype(jnp.int32), mode="drop")
    rpt = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(nnz_buf[:-1]).astype(jnp.int32)])
    out_idx = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    out_idx = jnp.where(is_real, out_idx, nnz_capacity)
    col_out = jnp.zeros(nnz_capacity, jnp.int32).at[out_idx].max(
        jnp.where(is_real, cols, 0), mode="drop")
    val_out = jnp.zeros(nnz_capacity, vals.dtype).at[out_idx].add(
        jnp.where(is_real, vals, 0), mode="drop")
    return CSR(rpt=rpt, col=col_out, val=val_out, shape=(m, n))
