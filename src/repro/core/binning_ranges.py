"""Bin ladders and binning-range selection (OpSparse §4.3, §5.6, §5.7).

The paper fixes per-kernel hash-table sizes (Tables 1–2) and then chooses
*binning ranges* — the largest row size admitted to each kernel — as
``floor(nominal_table_size / multiplier)``.  Its experiments (§6.3.3) find
``sym 1.2x`` and ``num 2x`` best on average; we keep those as defaults and
sweep the same grid in ``benchmarks/bench_binning_ranges.py``.

TPU adaptation (DESIGN.md §5): the ladder geometry (×2 per rung) is kept,
but the envelope is the ~16 MiB/core VMEM instead of the V100's 96 KB
shared memory, so an extended ladder with much larger top rungs is also
provided (``vmem_extended=True``).  Rows too large even for the top rung
fall back to the ESC (HBM) accumulator — the analog of the paper's
global-memory hash kernels (kernel8 symbolic / kernel7 numeric).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple

# Paper Table 1 (symbolic): nominal sizes whose /1.2 floors reproduce the
# published ranges 26 / 426 / 853 / 1706 / 3413 / 6826 / 10240 exactly.
SYMBOLIC_NOMINAL = (32, 512, 1024, 2048, 4096, 8192, 12288, 24576)
# Actual allocated table sizes (Table 1; kernel6/7 shave entries for the
# shared nnz counter -> 12287 / 24575 on GPU; we keep pow2 on TPU, VMEM
# scratch does not share space with the counter).
# opslint: disable=KRN001 -- paper Table 1 sizes: the top rungs are 3*4096 /
# 3*8192 by design; the hash probe falls back to the mod path for them.
SYMBOLIC_TABLE_SIZES = (32, 512, 1024, 2048, 4096, 8192, 12288, 24576)

# Paper Table 2 (numeric): nominal pow2 sizes; allocated sizes are
# nominal-1 on GPU (room for shared_offset).  /2 floors reproduce the
# published ranges 16 / 128 / 256 / 512 / 1024 / 2048 / 4096 exactly.
NUMERIC_NOMINAL = (32, 256, 512, 1024, 2048, 4096, 8192)
# opslint: disable=KRN001 -- paper Table 2 GPU-shaved sizes (pow2 - 1, room
# for shared_offset); deliberately non-pow-2, served by the mod probe path.
NUMERIC_TABLE_SIZES = (31, 255, 511, 1023, 2047, 4095, 8191)

# VMEM-extended ladders (TPU): one grid step resident per core; the table
# plus streaming buffers must fit the usable-VMEM budget.  int32 keys ->
# 4 B/entry symbolic; key+f32 value -> 8 B/entry numeric.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # usable slice of the ~16 MiB core VMEM
SYMBOLIC_NOMINAL_VMEM = SYMBOLIC_NOMINAL + (65536, 262144, 1048576)
NUMERIC_NOMINAL_VMEM = NUMERIC_NOMINAL + (32768, 131072, 524288)

# Row packing (multi-row VMEM tiles): the smallest int32 VMEM tile is
# (8, 128) = 1024 entries, so a rung whose table is smaller than that
# leaves most of the tile (and the VPU lanes striding it) idle when one
# grid step owns one row.  Low rungs therefore pack
# ``rows_per_block = PACK_TILE_ENTRIES // t_size`` rows per grid step as
# independent sub-tables inside one tile — rung occupancy scales with the
# tile instead of the row (the batched-by-row-class sizing of Liu &
# Vinter, and the paper's §5.6 utilization-vs-collision trade-off knob).
PACK_TILE_ENTRIES = 8 * 128


def rows_per_block_of(t_size: int) -> int:
    """Pow-2 sub-tables of size ``t_size`` packable into one VMEM tile.

    Kept a power of two so packed row-count buckets (pow-2 as well)
    always divide evenly into grid steps.
    """
    pack = 1
    while pack * 2 * t_size <= PACK_TILE_ENTRIES:
        pack *= 2
    return pack


@dataclasses.dataclass(frozen=True)
class BinLadder:
    """A bin ladder: per-rung table sizes + admitted row-size ranges.

    ``upper[i]`` is the largest row size (n_prod for symbolic, n_nz for
    numeric) admitted to rung ``i``; the last rung admits everything and is
    the fallback (global-memory-analog) rung.
    """

    table_sizes: Tuple[int, ...]   # per-rung accumulator table size
    upper: Tuple[int, ...]         # per-rung inclusive upper bound on row size
    multiplier: float              # the paper's range multiplier (1x/1.2x/...)
    # Pow-2 rows a packed kernel batches per grid step on each rung (1 on
    # rungs whose table already fills a VMEM tile).  Derived from
    # ``table_sizes`` when not given, so every construction site gets it.
    rows_per_block: Tuple[int, ...] = ()

    def __post_init__(self):
        if not self.rows_per_block:
            object.__setattr__(
                self, "rows_per_block",
                tuple(rows_per_block_of(t) for t in self.table_sizes))

    @property
    def num_bins(self) -> int:
        return len(self.table_sizes) + 1  # +1 fallback rung

    def fallback_threshold(self) -> int:
        """Rows strictly larger than this go to the fallback accumulator."""
        return self.upper[-1]


def make_ladder(nominal: Sequence[int], multiplier: float,
                table_sizes: Sequence[int] | None = None) -> BinLadder:
    upper = tuple(int(math.floor(s / multiplier)) for s in nominal)
    return BinLadder(
        table_sizes=tuple(table_sizes or nominal),
        upper=upper,
        multiplier=multiplier,
    )


def symbolic_ladder(multiplier: float = 1.2, *, vmem_extended: bool = False) -> BinLadder:
    nominal = SYMBOLIC_NOMINAL_VMEM if vmem_extended else SYMBOLIC_NOMINAL
    sizes = nominal if vmem_extended else SYMBOLIC_TABLE_SIZES
    return make_ladder(nominal, multiplier, sizes)


def numeric_ladder(multiplier: float = 2.0, *, vmem_extended: bool = False) -> BinLadder:
    nominal = NUMERIC_NOMINAL_VMEM if vmem_extended else NUMERIC_NOMINAL
    sizes = nominal if vmem_extended else NUMERIC_TABLE_SIZES
    return make_ladder(nominal, multiplier, sizes)


# The sweeps the paper runs in §6.3.3 (Figs 10 and 11).
SYMBOLIC_SWEEP = (1.0, 1.2, 1.5)
NUMERIC_SWEEP = (1.0, 1.5, 2.0, 3.0)
