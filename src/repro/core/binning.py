"""Two-pass binning (OpSparse §5.1, Algorithms 1–3) — global load balance.

The paper classifies rows by size (n_prod or n_nz) into bins, storing ALL
classified row ids in ONE length-M ``bins`` array plus tiny ``bin_size`` /
``bin_offset`` arrays — the minimum-metadata layout of Fig. 3.  Its GPU
implementation accumulates bin counts in shared memory (Alg 1), computes
offsets by exclusive-sum, then scatters row ids with shared-memory-staged
atomics (Alg 2), with a fast path (Alg 3) that emits the identity
permutation when every row fits the smallest bin.

TPU/JAX adaptation (DESIGN.md §2): pass 1 is a vectorized histogram (the
VMEM-staged Pallas variant lives in ``kernels/binning_pallas.py``); pass 2
is a stable counting-sort scatter — ``argsort(bin_of_row, stable)`` IS
"write row ids to their bin's slice" and preserves the paper's in-bin
row-id order.  The Alg-3 fast path is kept: when ``max(sizes) <= upper[0]``
the ``bins`` array is the identity and pass 2 is skipped (the orchestrator
checks the device-computed max on the host, exactly where the paper's
kernel-launch decision happens).

This module is ALSO the MoE token-router (models/moe.py): routing T tokens
to E experts is the same two-pass problem with sizes:=expert_id histograms.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .binning_ranges import BinLadder


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Binning:
    """Result of the two-pass binning — the paper's Fig. 3 metadata.

    bins:       (M,) int32 — row ids grouped by bin (one array, min metadata).
    bin_size:   (NUM_BIN,) int32.
    bin_offset: (NUM_BIN,) int32 exclusive-sum of bin_size.
    bin_of_row: (M,) int32 — which bin each row landed in.
    max_size:   () int32 — max row size (Alg 1 line 6/19's d_max_row_nnz).
    """

    bins: jax.Array
    bin_size: jax.Array
    bin_offset: jax.Array
    bin_of_row: jax.Array
    max_size: jax.Array

    def tree_flatten(self):
        return (self.bins, self.bin_size, self.bin_offset,
                self.bin_of_row, self.max_size), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_bins(self) -> int:
        return int(self.bin_size.shape[0])

    def rows_of_bin(self, b: int, capacity: int) -> Tuple[jax.Array, jax.Array]:
        """Row ids of bin ``b`` padded to static ``capacity``; returns
        (row_ids, count).  Padded slots hold row id 0 (callers mask)."""
        start = self.bin_offset[b]
        idx = start + jnp.arange(capacity, dtype=jnp.int32)
        valid = jnp.arange(capacity, dtype=jnp.int32) < self.bin_size[b]
        safe = jnp.where(valid, jnp.minimum(idx, self.bins.shape[0] - 1), 0)
        return jnp.where(valid, self.bins[safe], 0), self.bin_size[b]


def classify(sizes: jax.Array, upper: Tuple[int, ...]) -> jax.Array:
    """Bin index per row: first rung whose upper bound admits the size.

    ``searchsorted`` over the (sorted) rung bounds == the paper's Alg-1
    linear scan over ``r_range`` (the scan exits at the first admitting
    rung; searchsorted finds the same rung without the serial loop).
    Sizes above the last bound land in the fallback rung ``len(upper)``.
    """
    bounds = jnp.asarray(upper, dtype=sizes.dtype)
    return jnp.searchsorted(bounds, sizes, side="left").astype(jnp.int32)


@partial(jax.jit, static_argnames=("upper", "num_bins"))
def bin_rows(sizes: jax.Array, *, upper: Tuple[int, ...],
             num_bins: int) -> Binning:
    """Both passes, fused.  ``sizes`` is n_prod (symbolic) or n_nz (numeric).

    Pass 1 (Alg 1): histogram of bin ids -> bin_size; max of sizes.
    Offsets: exclusive-sum (the paper uses cub::DeviceScan; here cumsum).
    Pass 2 (Alg 2): stable counting-sort scatter of row ids.
    """
    m = sizes.shape[0]
    bin_of_row = classify(sizes, upper)
    bin_size = jnp.zeros(num_bins, dtype=jnp.int32).at[bin_of_row].add(1)
    bin_offset = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(bin_size)[:-1].astype(jnp.int32)])
    max_size = jnp.max(sizes) if m else jnp.zeros((), sizes.dtype)
    # Stable sort by bin id groups row ids per bin in-order — one length-M
    # array of metadata, the paper's Fig. 3 layout.
    bins = jnp.argsort(bin_of_row, stable=True).astype(jnp.int32)
    return Binning(bins=bins, bin_size=bin_size, bin_offset=bin_offset,
                   bin_of_row=bin_of_row, max_size=max_size)


@partial(jax.jit, static_argnames=("num_bins",))
def bin_rows_identity(sizes: jax.Array, num_bins: int) -> Binning:
    """Alg 3 fast path: every row fits bin 0 -> bins is the identity."""
    m = sizes.shape[0]
    bin_size = jnp.zeros(num_bins, jnp.int32).at[0].set(m)
    bin_offset = jnp.concatenate(
        [jnp.zeros(1, jnp.int32),
         jnp.full((num_bins - 1,), m, jnp.int32)])
    return Binning(
        bins=jnp.arange(m, dtype=jnp.int32),
        bin_size=bin_size,
        bin_offset=bin_offset,
        bin_of_row=jnp.zeros(m, jnp.int32),
        max_size=jnp.max(sizes) if m else jnp.zeros((), sizes.dtype),
    )


@partial(jax.jit, static_argnames=("num_bins",))
def bin_by_id(ids: jax.Array, num_bins: int):
    """Two-pass binning where the bin of each item IS its id.

    This is the MoE token-router (models/moe.py): routing T·k assignments
    to E experts is the paper's binning problem with ``bin_of_row := ids``:
    pass 1 histogram -> per-expert counts, exclusive-sum -> offsets, pass 2
    stable counting-sort scatter -> assignments grouped by expert in ONE
    length-(T·k) array (the paper's minimum-metadata bins layout).

    Returns (order, counts, offsets).
    """
    counts = jnp.zeros(num_bins, jnp.int32).at[ids].add(1)
    offsets = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    order = jnp.argsort(ids, stable=True).astype(jnp.int32)
    return order, counts, offsets


def bin_rows_for_ladder(sizes: jax.Array, ladder: BinLadder,
                        *, allow_fast_path: bool = True) -> Binning:
    """Orchestrator entry: host-checks the Alg-3 fast path, then bins.

    The host sync on ``max(sizes)`` mirrors the paper: the binning kernel
    writes d_max_row_nnz, and the HOST decides which second-pass kernel to
    launch.  Under jit tracing (no concrete values) we skip the fast path.
    """
    if allow_fast_path and not isinstance(sizes, jax.core.Tracer):
        max_size = int(jnp.max(sizes)) if sizes.shape[0] else 0
        if max_size <= ladder.upper[0]:
            return bin_rows_identity(sizes, num_bins=ladder.num_bins)
    return bin_rows(sizes, upper=ladder.upper, num_bins=ladder.num_bins)
