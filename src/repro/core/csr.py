"""CSR sparse-matrix container (JAX pytree).

The paper (OpSparse §2.1.1) uses CSR for A, B and C.  JAX requires static
array shapes, so the ``col``/``val`` arrays may be *padded* beyond the true
number of nonzeros; the authoritative nnz is ``rpt[-1]`` (device value).
Padded ``col`` entries are 0 and padded ``val`` entries are 0 so that any
masked consumer that forgets the mask still gathers in-bounds.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed-sparse-row matrix.

    Attributes:
      rpt:   (M+1,) int32 row pointers.  ``rpt[-1]`` is the true nnz.
      col:   (cap,) int32 column indices, ``cap >= nnz`` (padded with 0).
      val:   (cap,) values, same cap (padded with 0).
      shape: static (M, N).
    """

    rpt: jax.Array
    col: jax.Array
    val: jax.Array
    shape: Tuple[int, int]

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.rpt, self.col, self.val), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        rpt, col, val = children
        return cls(rpt=rpt, col=col, val=val, shape=aux)

    # -- properties --------------------------------------------------------
    @property
    def nrows(self) -> int:
        return self.shape[0]

    @property
    def ncols(self) -> int:
        return self.shape[1]

    @property
    def capacity(self) -> int:
        """Static storage capacity (>= true nnz)."""
        return int(self.col.shape[0])

    def nnz(self) -> jax.Array:
        """True number of nonzeros (device scalar)."""
        return self.rpt[-1]

    def nnz_per_row(self) -> jax.Array:
        """(M,) int32 row sizes — what the paper calls n_nz per row."""
        return self.rpt[1:] - self.rpt[:-1]

    def entry_mask(self) -> jax.Array:
        """(cap,) bool — True for real entries, False for padding."""
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.nnz()

    def row_ids(self) -> jax.Array:
        """(cap,) int32 — row index of every stored entry (M for padding).

        Vectorized CSR->COO expansion: ``searchsorted`` on the row pointers.
        """
        idx = jnp.arange(self.capacity, dtype=jnp.int32)
        rows = jnp.searchsorted(self.rpt, idx, side="right").astype(jnp.int32) - 1
        return jnp.where(self.entry_mask(), rows, self.nrows)

    # -- conversions (test / host utilities) -------------------------------
    @classmethod
    def from_dense(cls, dense, *, index_dtype=jnp.int32) -> "CSR":
        """Build an exact (unpadded) CSR from a dense matrix.  Host-side."""
        dense = np.asarray(dense)
        m, n = dense.shape
        rows, cols = np.nonzero(dense)
        order = np.lexsort((cols, rows))
        rows, cols = rows[order], cols[order]
        vals = dense[rows, cols]
        rpt = np.zeros(m + 1, dtype=np.int32)
        np.add.at(rpt, rows + 1, 1)
        rpt = np.cumsum(rpt).astype(np.int32)
        if len(cols) == 0:      # keep capacity >= 1 (zero-size gathers)
            cols = np.zeros(1, np.int32)
            vals = np.zeros(1, dense.dtype)
        return cls(
            rpt=jnp.asarray(rpt, dtype=index_dtype),
            col=jnp.asarray(cols, dtype=index_dtype),
            val=jnp.asarray(vals, dtype=dense.dtype),
            shape=(m, n),
        )

    @classmethod
    def from_parts(cls, rpt, col, val, shape) -> "CSR":
        return cls(
            rpt=jnp.asarray(rpt, dtype=jnp.int32),
            col=jnp.asarray(col, dtype=jnp.int32),
            val=jnp.asarray(val),
            shape=tuple(int(s) for s in shape),
        )

    def to_dense(self) -> jax.Array:
        """Dense (M, N) matrix.  For tests / oracles only."""
        m, n = self.shape
        rows = self.row_ids()
        mask = self.entry_mask()
        flat = jnp.zeros((m + 1) * n, dtype=self.val.dtype)
        lin = jnp.where(mask, rows * n + self.col, m * n)
        flat = flat.at[lin].add(jnp.where(mask, self.val, 0))
        return flat[: m * n].reshape(m, n)

    def row_slice(self, start: int, stop: int, *,
                  nrows: int | None = None,
                  capacity: int | None = None) -> "CSR":
        """Rows ``[start, stop)`` as a new CSR with rebased row pointers.

        The backbone of row-block sharding (Liu & Vinter's independent
        row-block sub-products): each shard of A is a ``row_slice`` whose
        product with the full B is an ordinary SpGEMM.  ``nrows`` /
        ``capacity`` pad the slice to static buckets (trailing empty rows,
        zero-filled storage) so every same-bucket slice presents identical
        static shapes to the engine.  ``start``/``stop``/``nrows``/
        ``capacity`` are static; the entry offsets stay on device, so
        slicing never forces a host sync.

        NB: ``capacity`` below the slice's true nnz silently truncates —
        callers that bucket capacities must verify (the engine checks the
        slice nnz against its learned shard buckets at dispatch).
        """
        return _row_slice(self, start, stop, nrows=nrows, capacity=capacity)

    def with_capacity(self, cap: int) -> "CSR":
        """Pad / truncate storage to a new static capacity."""
        cur = self.capacity
        if cap == cur:
            return self
        if cap > cur:
            col = jnp.zeros(cap, dtype=self.col.dtype).at[:cur].set(self.col)
            val = jnp.zeros(cap, dtype=self.val.dtype).at[:cur].set(self.val)
        else:
            col, val = self.col[:cap], self.val[:cap]
        return CSR(rpt=self.rpt, col=col, val=val, shape=self.shape)

    def block_until_ready(self) -> "CSR":
        jax.block_until_ready((self.rpt, self.col, self.val))
        return self


@partial(jax.jit, static_argnames=("start", "stop", "nrows", "capacity"))
def _row_slice(A: "CSR", start: int, stop: int, *,
               nrows: int | None = None,
               capacity: int | None = None) -> "CSR":
    n_real = stop - start
    out_rows = nrows if nrows is not None else n_real
    assert 0 <= start <= stop <= A.nrows, (start, stop, A.nrows)
    assert out_rows >= n_real, (out_rows, n_real)
    cap = int(capacity) if capacity is not None else A.capacity
    assert cap >= 1
    rpt_w = A.rpt[start:stop + 1]           # static slice: (n_real+1,)
    base = rpt_w[0]
    rpt = rpt_w - base
    if out_rows > n_real:                   # padded rows are empty
        rpt = jnp.concatenate(
            [rpt, jnp.full(out_rows - n_real, rpt[-1], dtype=rpt.dtype)])
    idx = base + jnp.arange(cap, dtype=jnp.int32)
    valid = idx < rpt_w[-1]
    safe = jnp.clip(idx, 0, A.capacity - 1)
    col = jnp.where(valid, A.col[safe], 0)
    val = jnp.where(valid, A.val[safe], 0)
    return CSR(rpt=rpt, col=col, val=val, shape=(out_rows, A.ncols))


@partial(jax.jit, static_argnames=("nnz_capacity",))
def gather_rows(A: "CSR", rows: jax.Array, valid: jax.Array,
                nnz_capacity: int | None = None) -> "CSR":
    """Extract a sub-CSR of the given rows (padded row slots allowed).

    Used by the global-memory-analog fallback rung: rows too large for the
    top VMEM hash table are gathered and handed to the ESC accumulator.
    ``rows`` may contain out-of-range ids where ``valid`` is False.
    """
    r_cap = rows.shape[0]
    cap = int(nnz_capacity) if nnz_capacity is not None else A.capacity
    safe_rows = jnp.clip(rows, 0, A.nrows - 1)
    sizes = jnp.where(valid, A.nnz_per_row()[safe_rows], 0).astype(jnp.int32)
    rpt_sub = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(sizes).astype(jnp.int32)])
    t = jnp.arange(cap, dtype=jnp.int32)
    sr = jnp.searchsorted(rpt_sub[:-1], t, side="right").astype(jnp.int32) - 1
    sr = jnp.clip(sr, 0, r_cap - 1)
    off = t - rpt_sub[sr]
    src = jnp.minimum(A.rpt[safe_rows[sr]] + off, max(A.capacity - 1, 0))
    t_valid = t < rpt_sub[-1]
    col = jnp.where(t_valid, A.col[src], 0)
    val = jnp.where(t_valid, A.val[src], 0)
    return CSR(rpt=rpt_sub, col=col, val=val, shape=(r_cap, A.ncols))


def random_csr(key, m: int, n: int, *, avg_nnz_per_row: float,
               max_nnz_per_row: int | None = None,
               dtype=jnp.float32, distribution: str = "uniform") -> CSR:
    """Synthetic sparse matrix generator (host-side, numpy RNG).

    ``distribution``:
      - "uniform":每 row size ~ Poisson(avg) clipped to [0, max].
      - "powerlaw": heavy-tailed row sizes (a few very large rows) — models
        matrices like webbase-1M with max_nnz/row >> mean.
      - "banded": FEM-like band structure (rows hit nearby columns) — models
        cant/consph/pwtk style matrices with high compression ratios.
    """
    seed = int(jax.random.bits(key, dtype=jnp.uint32)) if hasattr(key, "dtype") else int(key)
    rng = np.random.default_rng(seed)
    max_r = max_nnz_per_row or max(1, int(avg_nnz_per_row * 8))
    max_r = min(max_r, n)
    if distribution == "uniform":
        sizes = rng.poisson(avg_nnz_per_row, size=m)
    elif distribution == "powerlaw":
        sizes = np.minimum((rng.pareto(1.5, size=m) + 1.0) * avg_nnz_per_row * 0.5, max_r)
    elif distribution == "banded":
        sizes = rng.normal(avg_nnz_per_row, avg_nnz_per_row * 0.15, size=m)
    else:
        raise ValueError(f"unknown distribution {distribution!r}")
    sizes = np.clip(sizes.astype(np.int64), 0, max_r)

    cols_list = []
    for i in range(m):
        s = int(sizes[i])
        if s == 0:
            cols_list.append(np.empty(0, dtype=np.int32))
            continue
        if distribution == "banded":
            center = int(i * n / max(m, 1))
            lo = max(0, center - 2 * s)
            hi = min(n, lo + 4 * s + 1)
            cand = rng.choice(hi - lo, size=min(s, hi - lo), replace=False) + lo
        else:
            cand = rng.choice(n, size=s, replace=False)
        cols_list.append(np.sort(cand).astype(np.int32))
    sizes = np.array([len(c) for c in cols_list], dtype=np.int32)
    rpt = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    cap = max(int(rpt[-1]), 1)     # capacity >= 1 (zero-size gathers)
    col = np.zeros(cap, np.int32)
    if rpt[-1]:
        col[:rpt[-1]] = np.concatenate(cols_list).astype(np.int32)
    val = np.zeros(cap, np.dtype(dtype).name if dtype != jnp.bfloat16
                   else np.float32)
    val[:rpt[-1]] = rng.standard_normal(int(rpt[-1]))
    return CSR(rpt=jnp.asarray(rpt), col=jnp.asarray(col),
               val=jnp.asarray(val, dtype=dtype), shape=(m, n))
