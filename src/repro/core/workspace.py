"""Fused metadata workspace + shared arena (OpSparse §5.3–§5.5 adaptation).

The paper's metadata (the ``bins`` array, ``bin_size``, ``bin_offset``, the
max-row-size cell) is summed up and allocated with ONE ``cudaMalloc``; the
``n_prod``/``n_nz`` vectors reuse the ``C.rpt`` allocation.  The JAX analog
of repeated ``cudaMalloc`` cost is repeated *buffer allocation + executable
re-specialization*: we carve all binning metadata out of one flat int32
buffer whose shape depends only on (M, NUM_BIN), and **donate** it between
the symbolic and numeric binning calls so XLA reuses the same HBM block.

Layout (int32 cells):   [ bins : M | bin_size : NB | bin_offset : NB | max : 1 ]

The second half of this module generalizes the discipline across PLANS:
an :class:`Arena` of pow-2-size-bucketed device buffers that specialized
plans *lease* at dispatch and return at finalize.  The leased buffers ride
through each steady-state executable as donated arguments returned as
outputs, so XLA aliases one HBM block across every request that shares a
size bucket — the §5.4 alloc/exec-overlap analog, but process-wide instead
of per-plan.  The arena keeps exact host-side byte accounting (in-use,
reserved, peak, lease hit/miss) so a memory governor
(:class:`repro.engine.autotune.MemoryGovernor`) can bound the total and
degrade gracefully under pressure instead of multiplying buffers per plan.
"""
from __future__ import annotations

import dataclasses
import threading
from functools import lru_cache, partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .binning import Binning, classify


def next_bucket(n: int, *, minimum: int = 16) -> int:
    """Pow-2 shape bucket — bounds both padding waste (<2x) and the number
    of distinct compiled executables (the recompile<->cudaMalloc analog).

    The ONE shared copy: ``core.spgemm`` (storage/capacity buckets), the
    hash drivers (per-rung row-count buckets, ``minimum=8``), and the
    engine's progressive allocation all bucket through here.
    """
    b = minimum
    while b < n:
        b <<= 1
    return b


@dataclasses.dataclass(frozen=True)
class WorkspacePlan:
    m: int
    num_bins: int

    @property
    def size(self) -> int:
        return self.m + 2 * self.num_bins + 1

    def alloc(self) -> jax.Array:
        """The single fused allocation."""
        return jnp.zeros(self.size, dtype=jnp.int32)

    def views(self, buf: jax.Array) -> Binning:
        m, nb = self.m, self.num_bins
        return Binning(
            bins=buf[:m],
            bin_size=buf[m:m + nb],
            bin_offset=buf[m + nb:m + 2 * nb],
            bin_of_row=classify_placeholder(m),
            max_size=buf[m + 2 * nb],
        )


def classify_placeholder(m: int) -> jax.Array:
    return jnp.zeros(m, dtype=jnp.int32)


@partial(jax.jit, static_argnames=("upper", "num_bins", "m"),
         donate_argnums=(1,))
def bin_rows_into(sizes: jax.Array, buf: jax.Array, *,
                  upper: Tuple[int, ...], num_bins: int, m: int) -> jax.Array:
    """Two-pass binning writing ALL metadata into the donated fused buffer.

    Same math as ``binning.bin_rows`` but the outputs land in one buffer:
    XLA reuses the donated HBM block across the symbolic/numeric binning
    steps — the single-allocation discipline of §5.3.
    """
    bin_of_row = classify(sizes, upper)
    bin_size = jnp.zeros(num_bins, jnp.int32).at[bin_of_row].add(1)
    bin_offset = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(bin_size)[:-1].astype(jnp.int32)])
    bins = jnp.argsort(bin_of_row, stable=True).astype(jnp.int32)
    max_size = (jnp.max(sizes) if m else jnp.zeros((), sizes.dtype)).astype(jnp.int32)
    out = jnp.concatenate(
        [bins, bin_size, bin_offset, max_size[None]])
    return out


def binning_from_buffer(buf: jax.Array, sizes: jax.Array,
                        plan: WorkspacePlan, upper) -> Binning:
    m, nb = plan.m, plan.num_bins
    return Binning(
        bins=buf[:m],
        bin_size=buf[m:m + nb],
        bin_offset=buf[m + nb:m + 2 * nb],
        bin_of_row=classify(sizes, upper),
        max_size=buf[m + 2 * nb],
    )


# ---------------------------------------------------------------------------
# Shared size-bucketed workspace arena (§5.4 alloc/exec overlap, plan-wide).
# ---------------------------------------------------------------------------

class ArenaPressureError(RuntimeError):
    """The governor cap left no room for a workspace lease and every
    degradation rung (reclaim, forced trim, fused->two-pass spill) was
    exhausted — the caller must apply backpressure (finalize in-flight
    work to return leases) or raise the cap."""


@dataclasses.dataclass(frozen=True)
class LeaseSpec:
    """Size class of one plan's leased workspace: an int32 buffer (the
    expansion's row/col ids) plus a value-dtype buffer (the expansion
    products), both in pow-2 cell counts so same-bucket plans share the
    arena's free-list entries (and hence the same HBM blocks)."""

    i32_cells: int
    val_cells: int
    val_dtype: str

    @property
    def nbytes(self) -> int:
        return (4 * int(self.i32_cells)
                + jnp.dtype(self.val_dtype).itemsize * int(self.val_cells))


class Lease:
    """One checked-out workspace (a pair of device buffers).

    Lifecycle: ``active`` from :meth:`Arena.acquire` until either
    :meth:`Arena.release` (buffers rebound to the executable's returned
    aliases and recycled into the free lists) or :meth:`Arena.forfeit`
    (cache eviction while in flight: the buffers were donated into a
    still-running executable, so they are *dropped from accounting*
    rather than recycled — recycling a donated-away block would hand a
    dangling buffer to the next plan).
    """

    __slots__ = ("spec", "i32", "val", "state", "device", "keys")

    def __init__(self, spec: LeaseSpec, i32: jax.Array, val: jax.Array,
                 device=None, keys=None):
        self.spec = spec
        self.i32 = i32
        self.val = val
        self.state = "active"
        self.device = device    # free-list key half: buffers are per-device
        # Free-list keys, computed once at acquire: release/forfeit sit on
        # the per-request hot path and must not re-stringify dtypes.
        self.keys = keys if keys is not None else Arena._buckets(spec, device)

    @property
    def active(self) -> bool:
        return self.state == "active"


class Arena:
    """Process-wide pool of pow-2-bucketed workspace buffers.

    Free lists are keyed by ``(dtype, pow-2 cell bucket)``; acquiring a
    spec whose buckets have idle buffers is a *lease hit* (zero new
    bytes), otherwise the missing buffers are allocated (a *miss*) and
    counted against ``bytes_reserved``.  All accounting is host-side
    Python int (exact, wrap-proof):

      bytes_in_use    bytes leased out right now (dispatch -> finalize)
      bytes_free      idle bytes parked in the free lists
      bytes_reserved  in_use + free — what the arena holds in HBM, the
                      quantity a governor cap bounds
      peak_bytes      high-water mark of ``bytes_in_use`` (the benchmark
                      gate's "peak workspace bytes"; :meth:`reset_peak`
                      re-arms it after warmup)

    Thread-safe; the engine serializes leases per dispatch but caches
    may force-release (:meth:`forfeit`) from another thread.
    """

    def __init__(self, *, faults=None):
        # ``faults`` threads a ``repro.core.faults.FaultPlan`` through the
        # arena the way ``telemetry=`` rides the engine: a scheduled
        # ``lease_denial`` makes try_acquire behave as if the cap were
        # binding, so governor-ladder rungs are exercisable without real
        # pressure.  (The engine consults its own plan at the same site;
        # attach a plan to the arena OR the engine, not both, or the
        # site's visit counter advances twice per acquisition.)
        self.faults = faults
        self._lock = threading.Lock()
        self._free: Dict[Tuple[str, int], List[jax.Array]] = {}  # guarded-by: _lock
        self.bytes_in_use = 0       # guarded-by: _lock
        self.bytes_free = 0         # guarded-by: _lock
        self.peak_bytes = 0         # guarded-by: _lock
        self.lease_hits = 0         # guarded-by: _lock
        self.lease_misses = 0       # guarded-by: _lock
        self.pressure_events = 0    # guarded-by: _lock

    # -- introspection ------------------------------------------------------
    @property
    def bytes_reserved(self) -> int:
        return self.bytes_in_use + self.bytes_free

    @property
    def hit_rate(self) -> float:
        total = self.lease_hits + self.lease_misses
        return self.lease_hits / total if total else 0.0

    def reset_peak(self) -> None:
        with self._lock:
            self.peak_bytes = self.bytes_in_use

    # -- lease lifecycle ----------------------------------------------------
    @staticmethod
    @lru_cache(maxsize=1024)
    def _buckets(spec: LeaseSpec, device=None):
        """Free-list keys for a spec (memoized: specs are as few as the
        cached plans, and dtype stringification is hot-path cost)."""
        dtype = str(jnp.dtype(spec.val_dtype))
        return (("int32", next_bucket(max(int(spec.i32_cells), 1)), device),
                (dtype, next_bucket(max(int(spec.val_cells), 1)), device))

    @staticmethod
    @lru_cache(maxsize=64)
    def _bucket_bytes(key) -> int:
        return jnp.dtype(key[0]).itemsize * key[1]

    def try_acquire(self, spec: LeaseSpec,
                    cap_bytes: Optional[int] = None,
                    device=None) -> Optional[Lease]:
        """Lease a buffer pair, or ``None`` when allocating the missing
        buffers would push ``bytes_reserved`` past ``cap_bytes``.  A spec
        fully served from the free lists always succeeds (no new bytes),
        even over an already-exceeded cap — reuse never makes things
        worse.  ``device`` pins the buffers (mesh-placed shard operands
        must share their workspace's device); free lists are per-device,
        so a buffer never migrates between devices through the pool."""
        if self.faults is not None \
                and self.faults.fire("lease_denial") is not None:
            return None
        keys = self._buckets(spec, device)
        with self._lock:
            free = [self._free.get(k) for k in keys]
            need_new = sum(self._bucket_bytes(k)
                           for k, f in zip(keys, free) if not f)
            if need_new and cap_bytes is not None \
                    and self.bytes_reserved + need_new > cap_bytes:
                return None
            bufs = []
            for k, f in zip(keys, free):
                if f:
                    bufs.append(f.pop())
                    self.bytes_free -= self._bucket_bytes(k)
                    self.lease_hits += 1
                else:
                    buf = jnp.zeros(k[1], dtype=k[0])
                    if device is not None:
                        buf = jax.device_put(buf, device)
                    bufs.append(buf)
                    self.lease_misses += 1
                self.bytes_in_use += self._bucket_bytes(k)
            self.peak_bytes = max(self.peak_bytes, self.bytes_in_use)
            return Lease(spec, bufs[0], bufs[1], device=device, keys=keys)

    def acquire(self, spec: LeaseSpec,
                cap_bytes: Optional[int] = None, device=None) -> Lease:
        lease = self.try_acquire(spec, cap_bytes, device)
        if lease is None:
            raise ArenaPressureError(
                f"lease of {spec.nbytes} bytes would exceed the governor "
                f"cap ({cap_bytes} bytes; {self.bytes_reserved} reserved)")
        return lease

    def release(self, lease: Lease,
                rebind: Optional[Tuple[jax.Array, jax.Array]] = None) -> None:
        """Return a lease's buffers to the free lists.

        ``rebind`` is the donation loop's second half: the steady-state
        executable takes the leased buffers as donated arguments and
        returns them as outputs (XLA aliases the outputs into the donated
        blocks), so the *returned* arrays — not the consumed input
        handles — are what the arena must recycle.  Idempotent, and a
        no-op for a lease the cache already forfeited."""
        with self._lock:
            if not lease.active:
                return
            lease.state = "released"
            if rebind is not None:
                lease.i32, lease.val = rebind
            for key, buf in zip(lease.keys, (lease.i32, lease.val)):
                self._free.setdefault(key, []).append(buf)
                nbytes = self._bucket_bytes(key)
                self.bytes_in_use -= nbytes
                self.bytes_free += nbytes

    def forfeit(self, lease: Lease) -> int:
        """Drop an in-flight lease from accounting WITHOUT recycling its
        buffers (cache eviction path: the buffers were donated into an
        executable that may still be running).  The HBM is returned to
        the allocator when the executable's outputs are garbage
        collected; the later :meth:`release` at finalize is a no-op.
        Returns the bytes dropped."""
        with self._lock:
            if not lease.active:
                return 0
            lease.state = "forfeited"
            nbytes = sum(self._bucket_bytes(k) for k in lease.keys)
            self.bytes_in_use -= nbytes
            return nbytes

    def reclaim(self) -> int:
        """Drop every idle free-list buffer (pressure rung 0); returns
        the bytes released back to the device allocator."""
        with self._lock:
            freed = self.bytes_free
            self._free.clear()
            self.bytes_free = 0
            return freed

    def note_pressure(self) -> None:
        with self._lock:
            self.pressure_events += 1


# The process-wide default arena: every engine that isn't handed an
# explicit Arena shares this one, so multi-engine (multi-tenant) traffic
# in one process is memory-bounded TOGETHER — the whole point of the
# §5.4 generalization.
_DEFAULT_ARENA: Optional[Arena] = None
_DEFAULT_ARENA_LOCK = threading.Lock()


def default_arena() -> Arena:
    global _DEFAULT_ARENA
    with _DEFAULT_ARENA_LOCK:
        if _DEFAULT_ARENA is None:
            _DEFAULT_ARENA = Arena()
        return _DEFAULT_ARENA


def reset_default_arena() -> None:
    """Drop the shared arena (tests that need clean accounting)."""
    global _DEFAULT_ARENA
    with _DEFAULT_ARENA_LOCK:
        _DEFAULT_ARENA = None
