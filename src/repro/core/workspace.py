"""Fused metadata workspace (OpSparse §5.3–§5.4 adaptation).

The paper's metadata (the ``bins`` array, ``bin_size``, ``bin_offset``, the
max-row-size cell) is summed up and allocated with ONE ``cudaMalloc``; the
``n_prod``/``n_nz`` vectors reuse the ``C.rpt`` allocation.  The JAX analog
of repeated ``cudaMalloc`` cost is repeated *buffer allocation + executable
re-specialization*: we carve all binning metadata out of one flat int32
buffer whose shape depends only on (M, NUM_BIN), and **donate** it between
the symbolic and numeric binning calls so XLA reuses the same HBM block.

Layout (int32 cells):   [ bins : M | bin_size : NB | bin_offset : NB | max : 1 ]
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .binning import Binning, classify


def next_bucket(n: int, *, minimum: int = 16) -> int:
    """Pow-2 shape bucket — bounds both padding waste (<2x) and the number
    of distinct compiled executables (the recompile<->cudaMalloc analog).

    The ONE shared copy: ``core.spgemm`` (storage/capacity buckets), the
    hash drivers (per-rung row-count buckets, ``minimum=8``), and the
    engine's progressive allocation all bucket through here.
    """
    b = minimum
    while b < n:
        b <<= 1
    return b


@dataclasses.dataclass(frozen=True)
class WorkspacePlan:
    m: int
    num_bins: int

    @property
    def size(self) -> int:
        return self.m + 2 * self.num_bins + 1

    def alloc(self) -> jax.Array:
        """The single fused allocation."""
        return jnp.zeros(self.size, dtype=jnp.int32)

    def views(self, buf: jax.Array) -> Binning:
        m, nb = self.m, self.num_bins
        return Binning(
            bins=buf[:m],
            bin_size=buf[m:m + nb],
            bin_offset=buf[m + nb:m + 2 * nb],
            bin_of_row=classify_placeholder(m),
            max_size=buf[m + 2 * nb],
        )


def classify_placeholder(m: int) -> jax.Array:
    return jnp.zeros(m, dtype=jnp.int32)


@partial(jax.jit, static_argnames=("upper", "num_bins", "m"),
         donate_argnums=(1,))
def bin_rows_into(sizes: jax.Array, buf: jax.Array, *,
                  upper: Tuple[int, ...], num_bins: int, m: int) -> jax.Array:
    """Two-pass binning writing ALL metadata into the donated fused buffer.

    Same math as ``binning.bin_rows`` but the outputs land in one buffer:
    XLA reuses the donated HBM block across the symbolic/numeric binning
    steps — the single-allocation discipline of §5.3.
    """
    bin_of_row = classify(sizes, upper)
    bin_size = jnp.zeros(num_bins, jnp.int32).at[bin_of_row].add(1)
    bin_offset = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(bin_size)[:-1].astype(jnp.int32)])
    bins = jnp.argsort(bin_of_row, stable=True).astype(jnp.int32)
    max_size = (jnp.max(sizes) if m else jnp.zeros((), sizes.dtype)).astype(jnp.int32)
    out = jnp.concatenate(
        [bins, bin_size, bin_offset, max_size[None]])
    return out


def binning_from_buffer(buf: jax.Array, sizes: jax.Array,
                        plan: WorkspacePlan, upper) -> Binning:
    m, nb = plan.m, plan.num_bins
    return Binning(
        bins=buf[:m],
        bin_size=buf[m:m + nb],
        bin_offset=buf[m + nb:m + 2 * nb],
        bin_of_row=classify(sizes, upper),
        max_size=buf[m + 2 * nb],
    )
