"""llama-3.2-vision-90b — VLM backbone [hf:meta-llama/Llama-3.2-11B-Vision].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; gated
cross-attention image layers every 5th layer.  The vision tower is a
STUB: input_specs() provides precomputed patch embeddings, per the brief.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8, d_ff=28672,
    vocab_size=128256, head_dim=128, cross_attn_every=5, vision_tokens=6400,
)
