"""falcon-mamba-7b — attention-free Mamba1 LM [arXiv:2410.05355].

64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0, d_ff=0,
    vocab_size=65024, ssm_state=16, mamba_version=1, mlp_type="none",
)
