"""Architecture configuration (one dataclass drives every model family)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | encoder | ssm | hybrid | moe | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention
    head_dim: Optional[int] = None   # default d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    mlp_type: str = "swiglu"         # swiglu | gelu | none

    # ssm (mamba)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    mamba_version: int = 1
    ssm_head_dim: int = 64           # mamba2 P
    ssm_chunk: int = 128             # chunked-scan length

    # hybrid (zamba2-style): one SHARED attention+MLP block applied after
    # every `attn_every` ssm layers
    attn_every: int = 0

    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # Dispatch-payload dtype for the EP collectives ("bfloat16" | "int8").
    # int8 is the beyond-paper optimization: per-token symmetric
    # quantization of the dispatched activations halves the all-to-all
    # bytes (the dominant roofline term of the MoE train cells).
    moe_dispatch_dtype: str = "bfloat16"

    # vlm (cross-attention image layers every `cross_attn_every` layers)
    cross_attn_every: int = 0
    vision_tokens: int = 0

    # blocked (flash-style) attention tile sizes; q_block is the KV
    # re-read divisor (total KV traffic = (S/q_block) * KV bytes)
    attn_q_block: int = 1024
    attn_k_block: int = 1024

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_encoder(self) -> bool:
        return self.family == "encoder"

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """May run the long_500k cell (SSM / hybrid archs)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/topology, tiny sizes."""
        kw = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 4 if self.family != "vlm" else 10),
            d_model=64,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=128,
            head_dim=None,
        )
        if self.num_heads:
            kw["num_heads"] = 4
            kw["num_kv_heads"] = max(1, 4 * self.num_kv_heads // max(self.num_heads, 1))
        if self.num_experts:
            kw["num_experts"] = 8
            kw["experts_per_token"] = 2
            kw["d_ff"] = 32
            # no-drop capacity so decode == full-forward exactly in tests
            # (capacity-drop behaviour is unit-tested separately)
            kw["moe_capacity_factor"] = 16.0
        if self.ssm_state:
            kw["ssm_state"] = min(self.ssm_state, 16)
            kw["ssm_head_dim"] = 16
            kw["ssm_chunk"] = 8
        if self.attn_every:
            kw["attn_every"] = 2
        if self.cross_attn_every:
            kw["cross_attn_every"] = 5
            kw["vision_tokens"] = 16
        return self.replace(**kw)
