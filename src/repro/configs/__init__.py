from .base import ArchConfig
from .registry import ARCHS, get_arch

__all__ = ["ArchConfig", "ARCHS", "get_arch"]
