"""hubert-xlarge — encoder-only audio backbone [arXiv:2106.07447].

48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504.  The modality
frontend (CNN feature extractor) is a STUB: input_specs() provides
precomputed frame embeddings (B, S, d_model), per the brief.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="encoder",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16, d_ff=5120,
    vocab_size=504, mlp_type="gelu",
)
