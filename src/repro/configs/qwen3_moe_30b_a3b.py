"""qwen3-moe-30b-a3b — MoE LM, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) d_ff=768/expert vocab=151936, qk_norm.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, d_ff=768,
    vocab_size=151936, head_dim=128, qk_norm=True, num_experts=128,
    experts_per_token=8,
)
