"""olmoe-1b-7b — MoE LM, 64 experts top-8 [arXiv:2409.02060].

16L d_model=2048 16H (kv=16) d_ff=1024/expert vocab=50304.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16, d_ff=1024,
    vocab_size=50304, num_experts=64, experts_per_token=8,
)
