"""Registry of the 10 assigned architectures (one module per arch)."""
from __future__ import annotations

from .base import ArchConfig
from .falcon_mamba_7b import CONFIG as FALCON_MAMBA_7B
from .hubert_xlarge import CONFIG as HUBERT_XLARGE
from .qwen3_1_7b import CONFIG as QWEN3_1_7B
from .minitron_4b import CONFIG as MINITRON_4B
from .internlm2_1_8b import CONFIG as INTERNLM2_1_8B
from .codeqwen15_7b import CONFIG as CODEQWEN15_7B
from .zamba2_1_2b import CONFIG as ZAMBA2_1_2B
from .olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from .qwen3_moe_30b_a3b import CONFIG as QWEN3_MOE_30B_A3B
from .llama32_vision_90b import CONFIG as LLAMA32_VISION_90B

ARCHS = {
    c.name: c for c in (
        FALCON_MAMBA_7B, HUBERT_XLARGE, QWEN3_1_7B, MINITRON_4B,
        INTERNLM2_1_8B, CODEQWEN15_7B, ZAMBA2_1_2B, OLMOE_1B_7B,
        QWEN3_MOE_30B_A3B, LLAMA32_VISION_90B,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
