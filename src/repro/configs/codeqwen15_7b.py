"""codeqwen1.5-7b — dense MHA LM [hf:Qwen/CodeQwen1.5-7B].

32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32, d_ff=13440,
    vocab_size=92416, head_dim=128,
)
