"""zamba2-1.2b — Mamba2 + shared attention hybrid [arXiv:2411.15242].

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.  One
SHARED attention+MLP block applied after every 6 Mamba2 layers (the
Zamba2 shared-block pattern).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32, d_ff=8192,
    vocab_size=32000, ssm_state=64, mamba_version=2, ssm_head_dim=64,
    attn_every=6, head_dim=64,
)
