"""Telemetry-driven adaptive execution policy (OpSparse §4.3, taken live).

The paper's central tuning claim is that the binning/hashing *policy* —
how much headroom, which bins run, how work is split — trades hash-
collision rate against hardware utilization and must be matched to the
workload (§4.3, §5.6); spECK makes the same point with per-matrix
lightweight statistics.  The engine's two remaining fixed policies were
exactly the ROADMAP's open items:

  * the static ``shards=`` knob — every request fans out into the same N
    row blocks no matter how small the product is, even though the merge
    finalizer dominates tiny products;
  * the fixed 2x hash-schedule headroom — stable streams keep paying the
    padded (masked) grid steps the headroom bought them on day one.

This module replaces both with state *learned from the telemetry the
engine already collects in its one finalize sync*:

:class:`AdaptivePolicy`
    The engine-level knobs (hysteresis thresholds, headroom bounds,
    shard sizing).  Immutable; one per engine.

:class:`PolicyState`
    The per-plan learned state, carried on :class:`~repro.engine.plan.
    SpgemmPlan` and serialized by ``PlanCache.dump/load``: the current
    headroom, the eviction-free streak, observed per-rung bin-size
    maxima, and the shard-count decision with the flop basis it was made
    from.  All counters are HOST-side Python ints — the device scalars
    they accumulate are int32 and a near-2^31 flop stream would wrap any
    fixed-width accumulator (the same guard ``core/analysis.row_flops``
    applies to its ``2 * nprod`` weights).

The headroom policy is the §5.1/§5.6 memory-vs-retrace trade-off made
dynamic: an overflow retrace doubles the headroom for the rebuild (the
stream jitters more than the schedule allowed), while a sustained
eviction-free streak re-derives the schedule from the *observed* bin
maxima at a shrunken headroom and swaps it in (one deliberate retrace)
iff that actually removes padded grid steps.  At most one trim fires per
overflow epoch, so a stable stream settles instead of oscillating.

The shard policy picks N so every shard carries enough flops to amortize
the merge finalizer, bounded by device occupancy (the data-axis device
count); a stream whose observed mean flops drifts outside a hysteresis
band around the decision basis is re-decided — shrinking to N=1 for tiny
products where merge overhead dominates.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.binning_ranges import BinLadder
from repro.core.workspace import next_bucket
from repro.kernels.spgemm_hash import (_ROW_BUCKET_MIN,
                                       fallback_capacity_bucket,
                                       schedule_bucket)

from .partition import clamp_shards


@dataclasses.dataclass(frozen=True)
class AdaptivePolicy:
    """Engine-level adaptive-policy knobs (one per engine, immutable).

    headroom_*      bounds and step sizes for the hash-schedule headroom:
                    ``init`` seeds fresh plans (the old fixed 2x),
                    ``grow`` multiplies on overflow (capped at ``max``),
                    ``shrink`` multiplies on a trim (floored at ``min`` —
                    the capacity-margin floor, below which pow-2 rounding
                    provides all remaining slack).
    trim_streak     eviction-free hot finalizes before a trim attempt.
    min_shard_flops flops one shard must carry to amortize the merge
                    finalizer (below it, fewer/zero shards).
    max_shards      hard cap on the learned shard count (``None`` = the
                    data-axis device count — per-shard occupancy).
    revise_period   finalized requests between shard-count reviews.
    revise_factor   hysteresis band: the observed mean must leave
                    ``[basis/f, basis*f]`` before N is re-decided.
    """

    headroom_init: float = 2.0
    headroom_min: float = 1.25
    headroom_max: float = 4.0
    headroom_grow: float = 2.0
    headroom_shrink: float = 0.75
    trim_streak: int = 16
    min_shard_flops: int = 1 << 21
    max_shards: Optional[int] = None
    revise_period: int = 8
    revise_factor: float = 4.0
    # plan_mode="estimate" knobs: the sampled-ratio tail quantile, the
    # sample size (pow-2 keeps the gather/sample-symbolic compiles
    # shared), and the bounds/steps of the ENGINE-level learned headroom
    # multiplier on the estimator's tail ratio (EstimatorState) — grown
    # on an estimate miss (overflow retrace of an estimated plan), shrunk
    # toward ``min`` after a sustained miss-free streak.
    est_quantile: float = 0.9
    est_sample_rows: int = 64
    est_headroom_init: float = 1.5
    est_headroom_min: float = 1.1
    est_headroom_max: float = 4.0
    est_headroom_grow: float = 2.0
    est_headroom_shrink: float = 0.9
    est_hit_streak: int = 16


@dataclasses.dataclass(frozen=True)
class PolicyState:
    """Per-plan learned policy state (lives on ``SpgemmPlan.policy``).

    Bin-size maxima are observed over the CURRENT eviction-free streak
    (reset on overflow and after a trim attempt), so a trim re-derives
    from what the stream does *now*, not what it did before the last
    regime change.  Flop telemetry windows between shard reviews.  Every
    field is a host Python int/float — JSON-serializable and wrap-proof.
    """

    headroom: float = 2.0
    streak: int = 0
    trimmed: bool = False        # one trim per overflow epoch (hysteresis)
    sym_max: Optional[Tuple[int, ...]] = None
    num_max: Optional[Tuple[int, ...]] = None
    sym_fall_max: int = 0
    num_fall_max: int = 0
    flops_total: int = 0         # window accumulator (host int64 semantics)
    flops_calls: int = 0
    shard_decision: Optional[int] = None
    shard_basis: int = 0         # mean flops the decision was made from
    # Provenance: True while the plan's buckets come from the sampling
    # estimator and no admitted finalize has confirmed them yet (cleared
    # on the first admit; a retrace re-derives exact buckets and also
    # clears it).  Serialized in cache dumps (format v4) so a warm-started
    # replica knows which loaded schedules are still unverified.
    estimated: bool = False

    # -- hash-schedule jitter tracking --------------------------------------
    def note_admit(self, sym_sizes: Sequence[int], sym_fall: int,
                   num_sizes: Optional[Sequence[int]] = None,
                   num_fall: int = 0) -> "PolicyState":
        """Fold one admitted (eviction-free) hot finalize's observed bin
        metadata into the streak maxima.  Inputs may be device int32
        scalars; everything is widened to Python int on entry."""
        sym = tuple(int(s) for s in sym_sizes)
        if self.sym_max is not None and len(self.sym_max) == len(sym):
            sym = tuple(max(a, b) for a, b in zip(self.sym_max, sym))
        num = self.num_max
        if num_sizes is not None:
            num = tuple(int(s) for s in num_sizes)
            if self.num_max is not None and len(self.num_max) == len(num):
                num = tuple(max(a, b) for a, b in zip(self.num_max, num))
        return dataclasses.replace(
            self, streak=self.streak + 1, sym_max=sym, num_max=num,
            sym_fall_max=max(self.sym_fall_max, int(sym_fall)),
            num_fall_max=max(self.num_fall_max, int(num_fall)))

    def note_overflow(self, policy: AdaptivePolicy) -> "PolicyState":
        """Overflow retrace: the stream jitters beyond the schedule — grow
        the headroom for the rebuild, restart the streak, re-arm trims."""
        return dataclasses.replace(
            self, headroom=min(self.headroom * policy.headroom_grow,
                               policy.headroom_max),
            streak=0, trimmed=False, sym_max=None, num_max=None,
            sym_fall_max=0, num_fall_max=0)

    def after_trim(self, policy: AdaptivePolicy) -> "PolicyState":
        """Post-trim-attempt state: shrunken headroom, fresh streak, and
        no further trims until an overflow opens a new epoch."""
        return dataclasses.replace(
            self, headroom=self.trim_headroom(policy), streak=0,
            trimmed=True, sym_max=None, num_max=None,
            sym_fall_max=0, num_fall_max=0)

    def trim_headroom(self, policy: AdaptivePolicy) -> float:
        """The headroom a trim re-derives with (one shrink step down)."""
        return max(policy.headroom_min,
                   self.headroom * policy.headroom_shrink)

    def wants_trim(self, policy: AdaptivePolicy) -> bool:
        return (not self.trimmed and self.sym_max is not None
                and self.streak >= policy.trim_streak)

    # -- shard-count telemetry ----------------------------------------------
    def note_flops(self, flops: int) -> "PolicyState":
        """Accumulate one finalized request's flop estimate (host int)."""
        return dataclasses.replace(
            self, flops_total=self.flops_total + int(flops),
            flops_calls=self.flops_calls + 1)

    @property
    def mean_flops(self) -> int:
        return self.flops_total // max(self.flops_calls, 1)

    def with_shard_decision(self, n: int, basis: int) -> "PolicyState":
        return dataclasses.replace(
            self, shard_decision=int(n), shard_basis=int(basis),
            flops_total=0, flops_calls=0)

    # -- estimate provenance -------------------------------------------------
    def with_estimated(self, flag: bool) -> "PolicyState":
        return dataclasses.replace(self, estimated=bool(flag))

    # -- persistence merge ---------------------------------------------------
    def union(self, other: "PolicyState") -> "PolicyState":
        """Monotone merge for cross-process cache loads: keep the larger
        observed maxima and the more conservative (larger) headroom; an
        identical pair merges to itself, so no-op loads stay no-ops."""
        def tmax(a, b):
            if a is None:
                return b
            if b is None or len(a) != len(b):
                return a
            return tuple(max(x, y) for x, y in zip(a, b))
        return PolicyState(
            headroom=max(self.headroom, other.headroom),
            streak=max(self.streak, other.streak),
            trimmed=self.trimmed and other.trimmed,
            sym_max=tmax(self.sym_max, other.sym_max),
            num_max=tmax(self.num_max, other.num_max),
            sym_fall_max=max(self.sym_fall_max, other.sym_fall_max),
            num_fall_max=max(self.num_fall_max, other.num_fall_max),
            flops_total=max(self.flops_total, other.flops_total),
            flops_calls=max(self.flops_calls, other.flops_calls),
            shard_decision=(self.shard_decision
                            if self.shard_decision is not None
                            else other.shard_decision),
            shard_basis=max(self.shard_basis, other.shard_basis),
            # Unverified taints the merge: a verified replica merging an
            # estimated peer must not launder the peer's buckets.
            estimated=self.estimated or other.estimated,
        )


# ---------------------------------------------------------------------------
# Estimator headroom tracking (plan_mode="estimate").
# ---------------------------------------------------------------------------

class EstimatorState:
    """Engine-level learned headroom for the sampling estimator.

    Mutable (like :class:`~repro.engine.stats.EngineStats`, unlike the
    per-plan immutable ``PolicyState``): the ratio tail is a property of
    the *stream*, not of one plan, so every estimated specialization
    shares one multiplier.  The same grow/shrink discipline as the hash
    headroom — an estimate miss (overflow retrace of estimated buckets)
    doubles it, a sustained miss-free streak of verified estimates steps
    it back toward the floor.
    """

    def __init__(self, policy: AdaptivePolicy):
        self._policy = policy
        self.headroom: float = policy.est_headroom_init
        self.hits = 0            # estimated plans confirmed by an admit
        self.misses = 0          # estimated plans corrected by a retrace
        self._streak = 0

    def note_hit(self) -> None:
        self.hits += 1
        self._streak += 1
        if self._streak >= self._policy.est_hit_streak:
            self._streak = 0
            self.headroom = max(self._policy.est_headroom_min,
                                self.headroom * self._policy.est_headroom_shrink)

    def note_miss(self) -> None:
        self.misses += 1
        self._streak = 0
        self.headroom = min(self._policy.est_headroom_max,
                            self.headroom * self._policy.est_headroom_grow)


# ---------------------------------------------------------------------------
# Shard-count selection.
# ---------------------------------------------------------------------------

def choose_shards(total_flops: int, nrows: int, devices: int,
                  policy: AdaptivePolicy, *, telemetry=None) -> int:
    """Shard count from a flop estimate and the device occupancy bound.

    Each shard must carry ``min_shard_flops`` to amortize the jitted
    merge finalizer (per-shard verify syncs + device concatenation), and
    there is no point fanning wider than the devices that could run the
    shards concurrently — so tiny products collapse to N=1 (unsharded:
    no merge at all) and large ones saturate the mesh.  All math is host
    Python int: a multi-billion-flop stream must not wrap.

    ``telemetry`` (duck-typed: anything with ``.event``) records the
    decision and its flop basis in the trace.
    """
    limit = (int(policy.max_shards) if policy.max_shards is not None
             else max(int(devices), 1))
    n = min(limit, int(total_flops) // max(int(policy.min_shard_flops), 1))
    n = clamp_shards(nrows, n)
    if telemetry is not None:
        telemetry.event("autotune.choose_shards", shards=n,
                        total_flops=int(total_flops), devices=int(devices))
    return n


def revise_shards(state: PolicyState, nrows: int, devices: int,
                  policy: AdaptivePolicy, *,
                  telemetry=None) -> Tuple[PolicyState, bool]:
    """Periodic shard-count review over the telemetry window.

    Every ``revise_period`` finalized requests, re-decide N from the
    window's mean flops — but only when the mean has left the hysteresis
    band around the decision basis, so a stream hovering near a sizing
    boundary doesn't flap plans (each flip costs a cold call).  Returns
    ``(state, revised)``; the window resets either way.  A revision is
    recorded on ``telemetry`` (duck-typed) when one fires.
    """
    if state.shard_decision is None or state.flops_calls < policy.revise_period:
        return state, False
    mean = state.mean_flops
    basis = max(state.shard_basis, 1)
    state = dataclasses.replace(state, flops_total=0, flops_calls=0)
    if (mean * policy.revise_factor >= basis
            and mean <= basis * policy.revise_factor):
        return state, False                  # within the hysteresis band
    n = choose_shards(mean, nrows, devices, policy)
    if n == state.shard_decision:
        return dataclasses.replace(state, shard_basis=mean), False
    if telemetry is not None:
        telemetry.event("autotune.revise_shards", shards=n,
                        prev_shards=state.shard_decision, mean_flops=mean)
    return state.with_shard_decision(n, mean), True


# ---------------------------------------------------------------------------
# Hash-schedule trimming.
# ---------------------------------------------------------------------------

def trim_buckets(maxima: Tuple[int, ...], current: Tuple[int, ...],
                 m: int, headroom: float,
                 packs: Optional[Tuple[int, ...]] = None) -> Tuple[int, ...]:
    """Re-derive one ladder's bin-count buckets from observed maxima.

    Mirrors ``spgemm_hash.host_schedule`` bit-for-bit (the shared
    :func:`~repro.kernels.spgemm_hash.schedule_bucket`), then takes the
    elementwise min with the current schedule — a trim only ever
    shrinks; rungs the streak never populated drop to 0 (statically
    absent, the biggest padding win).
    """
    m_cap = next_bucket(int(m), minimum=_ROW_BUCKET_MIN)
    return tuple(
        min(cur, schedule_bucket(
            s, m_cap=m_cap, headroom=headroom,
            pack=(packs[b] if packs is not None and b < len(packs) else 1)))
        for b, (s, cur) in enumerate(zip(maxima, current)))


def trim_fallback(fall_max: int, current: int, headroom: float,
                  active: bool) -> int:
    """Trimmed fallback-expansion capacity.

    ``active`` says whether any verified rung still uses the fallback
    expansion (either phase's last bucket nonzero for two-pass plans,
    sym's alone for fused) — when every fallback rung dropped the
    capacity drops to 0 (statically absent).  ``fall_max`` is the max of
    both phases' observed sub-products: the shared bucket must admit
    whichever phase expands more."""
    if not active:
        return 0
    if not int(fall_max):
        return current
    return min(current, fallback_capacity_bucket(fall_max,
                                                 headroom=headroom))


def trim_schedule(state: PolicyState, current, *, m: int,
                  sym_ladder: BinLadder, packed: bool, fused: bool,
                  policy: AdaptivePolicy):
    """Derive the trimmed :class:`HashSchedule` fields from a streak's
    observed maxima, or ``None`` when trimming would change nothing.

    Returns ``(sym_buckets, num_buckets, fall_prod)`` ready for
    ``HashSchedule`` — the caller owns the dataclass to keep this module
    import-light (plan.py imports us for ``PolicyState``).  Fused plans
    observe (and trim) only the symbolic side — there is no numeric
    probe pass — so their numeric buckets ride along unchanged, and the
    shared fallback capacity is sized to the max of both phases'
    observed sub-products (the state keeps them separate so policy
    serialization and ``note_admit`` call sites are unchanged; they
    merge only here).
    """
    if state.sym_max is None:
        return None
    headroom = state.trim_headroom(policy)
    # Packing now applies to the standalone symbolic kernels too, so a
    # packed plan's sym buckets stay rows_per_block-aligned whether or
    # not the numeric side is fused into the same table build.
    packs = sym_ladder.rows_per_block if packed else None
    sym = trim_buckets(state.sym_max, current.sym_row_buckets, m, headroom,
                       packs)
    num = current.num_row_buckets
    if not fused and state.num_max is not None:
        num = trim_buckets(state.num_max, num, m, headroom)
    active = bool(sym[-1]) or (not fused and bool(num[-1]))
    fall_max = max(state.sym_fall_max,
                   0 if fused else state.num_fall_max)
    fall = trim_fallback(fall_max, current.fall_prod_bucket, headroom, active)
    if (sym == tuple(current.sym_row_buckets)
            and num == tuple(current.num_row_buckets)
            and fall == current.fall_prod_bucket):
        return None
    return sym, num, fall


# ---------------------------------------------------------------------------
# Memory governor.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MemoryGovernor:
    """Bound on total arena bytes with a graceful-degradation ladder.

    ``cap_bytes`` bounds the arena's *reserved* bytes (leased + pooled);
    ``None`` means unbounded (every lease is granted).  When a lease
    would exceed the cap the executor walks the ladder, cheapest rung
    first:

      1. ``Arena.reclaim()`` — drop idle pooled buffers and retry.
      2. forced headroom trim (``trim_under_pressure``) — re-derive the
         hash schedule at ``headroom_min`` from the streak's observed
         maxima, shrinking the plan's lease spec, and retry.
      3. fused->two-pass spill (``spill_fused``) — route the request
         through the unleased two-pass oracle path for this call.
      4. :class:`~repro.core.workspace.ArenaPressureError` — the caller
         must finalize in-flight work (returning leases) or raise the
         cap; ``SpgemmEngine.drain`` does exactly that before re-raising.

    The serving layer (``repro.serve.spgemm_service``) extends the
    ladder above rung 4 with request-level rungs (backoff retry, shed
    sharding, fused->two-pass spill, reject-with-retry-after);
    ``retry_after_s`` is the backpressure hint a rejected request
    carries back to its client.
    """

    cap_bytes: Optional[int] = None
    trim_under_pressure: bool = True
    spill_fused: bool = True
    retry_after_s: float = 0.05
