"""Execution plans: everything derivable about a SpGEMM call BEFORE data.

OpSparse overlaps result-matrix allocation with kernel execution (§5.4) and
fuses all metadata into one allocation (§5.3) because on a GPU the per-call
setup cost is ``cudaMalloc`` + launch configuration.  In the JAX port the
analogous per-call cost is *trace + compile*: every distinct static shape
is a new executable.  An :class:`SpgemmPlan` therefore captures the full
static configuration of a call — the ladder pair, the accumulator method,
the pow-2 capacity buckets, and the donated fused-metadata buffer layout —
keyed by *signatures* of the operands rather than the operands themselves,
so that every request landing in the same shape bucket shares one plan
(and, via :mod:`repro.engine.cache`, one compiled executable).

Plans are progressive (Liu & Vinter-style ahead-of-time allocation): a
fresh plan has no product/nnz capacity buckets (they depend on data); the
first execution *learns* them and :meth:`SpgemmPlan.with_capacities`
produces the specialized plan that steady-state traffic runs against.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.binning_ranges import BinLadder
from repro.core.csr import CSR
from repro.core.spgemm import SpgemmConfig, next_bucket
from repro.core.workspace import LeaseSpec, WorkspacePlan

from .autotune import PolicyState
from .partition import ShardSpec


@dataclasses.dataclass(frozen=True)
class MatrixSig:
    """Shape/nnz-bucket signature of one CSR operand.

    Two matrices with the same signature are interchangeable for planning:
    same static shapes after padding ``col``/``val`` to ``cap_bucket``
    (pow-2 — the recompile analog of §5.4's cudaMalloc bucketing), hence
    the same traced executables.
    """

    nrows: int
    ncols: int
    cap_bucket: int     # pow-2 bucket of the col/val storage capacity
    dtype: str          # value dtype name

    @classmethod
    def of(cls, M: CSR) -> "MatrixSig":
        return cls(nrows=M.nrows, ncols=M.ncols,
                   cap_bucket=next_bucket(M.capacity),
                   dtype=str(M.val.dtype))


# The cache key.  Partition-awareness threads through it via
# ``SpgemmConfig.shards``: a sharded parent plan (shards=N) and the
# unsharded plan of the same operands are distinct cache entries, and each
# per-shard sub-dispatch keys on its SLICE's signature (pow-2 row/storage
# buckets from the plan's ShardSpec) with shards=1 — so shard plans are
# ordinary plans, shared across shards/requests whose buckets coincide.
PlanKey = Tuple[MatrixSig, MatrixSig, SpgemmConfig]


@dataclasses.dataclass(frozen=True)
class HashSchedule:
    """Learned static launch schedule for the hash method (§5.1, §5.5).

    The paper's per-call host decision — which bin kernels to launch, with
    how many rows each — becomes part of the specialized plan: a pow-2
    row-count bucket per rung of each ladder (last entry = the ESC
    fallback rung; 0 = rung statically absent) plus pow-2 capacities for
    the fallback rung's sub-product expansions.  With these static, the
    whole hash pipeline traces into one executable; the engine's finalize
    sync verifies the actual bin sizes fit and grows the schedule
    (monotonically, via :meth:`union`) on overflow.
    """

    sym_row_buckets: Tuple[int, ...]
    num_row_buckets: Tuple[int, ...]
    fall_prod_bucket: int   # one shared sym/num fallback expansion capacity

    def union(self, other: "HashSchedule") -> "HashSchedule":
        """Elementwise max — schedules only ever grow (progressive
        allocation; keeps every previously-admitted request admitted)."""
        return HashSchedule(
            sym_row_buckets=tuple(
                max(a, b) for a, b in zip(self.sym_row_buckets,
                                          other.sym_row_buckets)),
            num_row_buckets=tuple(
                max(a, b) for a, b in zip(self.num_row_buckets,
                                          other.num_row_buckets)),
            fall_prod_bucket=max(self.fall_prod_bucket,
                                 other.fall_prod_bucket),
        )

    def admits(self, sym_bin_sizes, num_bin_sizes, sym_fall_prod: int,
               num_fall_prod: int) -> bool:
        """Whether an executed run's observed bin metadata fit the static
        schedule it was dispatched with (rows beyond a bucket — or
        fallback products beyond their capacity — were truncated).  Both
        phases share ``fall_prod_bucket`` (one arena bucket, one traced
        expansion shape), so the bound is on their max."""
        return (
            self.admits_fused(sym_bin_sizes, sym_fall_prod)
            and all(int(s) <= b for s, b in zip(num_bin_sizes,
                                                self.num_row_buckets))
            and int(num_fall_prod) <= self.fall_prod_bucket)

    def admits_fused(self, sym_bin_sizes, sym_fall_prod: int) -> bool:
        """Fused-pipeline admission (``SpgemmConfig.fuse_numeric``): the
        one table build is scheduled off the SYMBOLIC ladder alone — there
        is no numeric binning/probe pass to verify.  When a packed config
        learned this schedule the sym buckets are additionally multiples
        of each rung's ``rows_per_block`` (``host_schedule(packs=...)``;
        pow-2 unions preserve the alignment)."""
        return (
            all(int(s) <= b for s, b in zip(sym_bin_sizes,
                                            self.sym_row_buckets))
            and int(sym_fall_prod) <= self.fall_prod_bucket)


@dataclasses.dataclass(frozen=True)
class SpgemmPlan:
    """Immutable pre-data execution plan for one (A_sig, B_sig, config).

    Fields derivable before any data arrives:
      a_sig / b_sig    operand signatures (shapes + storage buckets).
      config           the full SpgemmConfig (method, multipliers, ...).
      sym_ladder       symbolic bin ladder (paper Table 1 ranges).
      num_ladder       numeric bin ladder (paper Table 2 ranges).
      sym_workspace    donated fused-metadata buffer layout for the
      num_workspace    symbolic/numeric binning steps (§5.3 analog).

    Learned on first execution (progressive allocation):
      prod_bucket      pow-2 capacity for the intermediate-product
                       expansion (``None`` until learned).
      nnz_bucket       pow-2 capacity for C.col/C.val (``None`` until
                       learned).
      hash_schedule    static per-rung launch schedule (hash method only;
                       ``None`` until learned — ESC plans never set it).
      shard_spec       learned row-block partition (sharded plans only,
                       ``config.shards > 1``; ``None`` until the cold call
                       balances the blocks by cumulative flop estimate).
      policy           adaptive-policy state (``engine/autotune``): the
                       tracked headroom/jitter for hash plans, the shard
                       decision for AUTO_SHARDS plans.  Updated without
                       dropping executables (it never enters a trace);
                       persisted by ``PlanCache.dump/load``.
    """

    a_sig: MatrixSig
    b_sig: MatrixSig
    config: SpgemmConfig
    sym_ladder: BinLadder
    num_ladder: BinLadder
    sym_workspace: WorkspacePlan
    num_workspace: WorkspacePlan
    prod_bucket: Optional[int] = None
    nnz_bucket: Optional[int] = None
    hash_schedule: Optional[HashSchedule] = None
    shard_spec: Optional[ShardSpec] = None
    policy: Optional[PolicyState] = None

    @property
    def signature(self) -> PlanKey:
        """The cache key: ladders/workspaces are derived from it."""
        return (self.a_sig, self.b_sig, self.config)

    @property
    def is_specialized(self) -> bool:
        """True once everything the jitted steady state needs is learned —
        the capacity buckets, plus the launch schedule for hash plans.
        A sharded parent plan only needs its partition: the capacities
        live on the per-shard sub-plans."""
        if self.config.shards > 1:
            return self.shard_spec is not None
        caps = self.prod_bucket is not None and self.nnz_bucket is not None
        if self.config.method == "hash":
            return caps and self.hash_schedule is not None
        return caps

    def with_capacities(self, prod_bucket: int,
                        nnz_bucket: int) -> "SpgemmPlan":
        """Specialized plan with learned (or grown) capacity buckets."""
        return dataclasses.replace(self, prod_bucket=int(prod_bucket),
                                   nnz_bucket=int(nnz_bucket))

    def with_hash_schedule(self, schedule: HashSchedule) -> "SpgemmPlan":
        """Plan with a learned (or grown) static hash launch schedule."""
        return dataclasses.replace(self, hash_schedule=schedule)

    def with_shard_spec(self, spec: ShardSpec) -> "SpgemmPlan":
        """Plan with a learned (or per-shard-grown) row-block partition."""
        return dataclasses.replace(self, shard_spec=spec)

    def with_policy(self, state: PolicyState) -> "SpgemmPlan":
        """Plan carrying updated adaptive-policy state (same signature,
        same traced shapes — cached executables stay valid)."""
        return dataclasses.replace(self, policy=state)

    def admits(self, A: CSR, B: CSR) -> bool:
        """Whether (A, B) land in this plan's shape buckets."""
        return MatrixSig.of(A) == self.a_sig and MatrixSig.of(B) == self.b_sig

    def workspace_spec(self) -> Optional[LeaseSpec]:
        """Size class of the arena lease this plan's steady state wants,
        or ``None`` when the plan allocates nothing leasable: not yet
        specialized, a sharded parent (leases live on the per-shard
        sub-plans), or a hash plan whose fallback rung is statically
        absent (``fall_prod_bucket == 0`` — nothing to expand).

        ESC leases the intermediate-product expansion (row ids + col ids
        as one int32 buffer, values separately); hash plans lease the
        fallback rung's sub-expansion with the same 2:1 int32:value cell
        split.  Both phases of a two-pass hash plan share ONE lease —
        the shared ``fall_prod_bucket`` is what makes that sound."""
        if not self.is_specialized or self.config.shards > 1:
            return None
        dtype = self.a_sig.dtype
        if self.config.method == "hash":
            fall = self.hash_schedule.fall_prod_bucket
            if not fall:
                return None
            return LeaseSpec(i32_cells=2 * fall, val_cells=fall,
                             val_dtype=dtype)
        return LeaseSpec(i32_cells=2 * self.prod_bucket,
                         val_cells=self.prod_bucket, val_dtype=dtype)


def plan(a_sig: MatrixSig, b_sig: MatrixSig,
         config: SpgemmConfig = SpgemmConfig()) -> SpgemmPlan:
    """Construct the pre-data plan for a signature pair.

    Everything here is derivable without looking at values: the ladders
    come from the config's multipliers, the workspace layouts from
    (M, NUM_BIN) alone.  Capacity buckets stay unlearned (``None``).
    """
    assert a_sig.ncols == b_sig.nrows, (a_sig, b_sig)
    if config.plan_mode not in ("exact", "estimate"):
        raise ValueError(
            f"unknown plan_mode {config.plan_mode!r} "
            "(expected 'exact' or 'estimate')")
    sym_ladder, num_ladder = config.ladders()
    return SpgemmPlan(
        a_sig=a_sig, b_sig=b_sig, config=config,
        sym_ladder=sym_ladder, num_ladder=num_ladder,
        sym_workspace=WorkspacePlan(a_sig.nrows, sym_ladder.num_bins),
        num_workspace=WorkspacePlan(a_sig.nrows, num_ladder.num_bins),
    )


def plan_key(A: CSR, B: CSR, config: SpgemmConfig) -> PlanKey:
    """Cache key for a concrete request — signatures, not arrays."""
    return (MatrixSig.of(A), MatrixSig.of(B), config)
