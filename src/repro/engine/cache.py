"""LRU plan cache — the recompile<->cudaMalloc analog of OpSparse §5.4.

The paper amortizes allocation by overlapping ``cudaMalloc`` with kernel
execution; the JAX port's dominant repeat cost is tracing + XLA
compilation.  The cache holds, per plan signature, the specialized
:class:`~repro.engine.plan.SpgemmPlan` AND the jitted steady-state
executable built for it, so a repeat shape bucket skips tracing entirely.

Hit/miss/eviction counters are first-class (the acceptance benchmark
reports the hit rate); eviction drops the executable reference, which
releases the underlying compiled program once JAX's own caches let go.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import threading
from collections import OrderedDict
from typing import Callable, Iterable, List, Optional, Tuple

from repro.core.spgemm import SpgemmConfig
from repro.core.workspace import Arena, Lease, next_bucket

from . import telemetry as telemetry_mod
from .autotune import PolicyState
from .partition import ShardSpec
from .plan import HashSchedule, MatrixSig, PlanKey, SpgemmPlan
from .plan import plan as make_plan
from .stats import PlanStats, plan_label

# v1: pre-adaptive-policy payloads (no ``policy`` blob; hash schedules may
# predate row packing / fusion, so their sym buckets were never
# pack-aligned).  v2 adds the policy blob.  v3 merges the per-phase
# fallback capacities into one shared ``fall_prod_bucket`` — loading a
# v1/v2 schedule takes the max of its two buckets (monotone: every
# previously-admitted request stays admitted).  v4 adds estimation-based
# planning provenance: configs carry ``plan_mode`` and policies the
# ``estimated`` flag (both serialized through ``dataclasses.asdict``, so
# the schema change is free) — older blobs load via the dataclass
# defaults ("exact" / False: pre-estimator plans were all exact-sized).
# ``load`` accepts all four and re-derives pack alignment for packed
# plans either way — see ``_align_schedule_for_packing``.
_DUMP_VERSION = 4
_LOADABLE_VERSIONS = (1, 2, 3, 4)


@dataclasses.dataclass
class CacheEntry:
    """A cached plan plus its compiled artifacts and telemetry."""

    plan: SpgemmPlan
    executable: Optional[Callable] = None   # jitted hot path (ESC or hash)
    stats: PlanStats = dataclasses.field(default_factory=PlanStats)
    leases: List[Lease] = dataclasses.field(default_factory=list)
    last_used: int = 0    # monotone LRU stamp (0 = never hit since insert)


class PlanCache:
    """Thread-safe LRU cache keyed by plan signature.

    With an ``arena`` attached, eviction is arena-aware: evicting an
    entry forfeits its outstanding workspace leases (the arena drops
    their bytes from accounting — the buffers were donated into possibly
    still-running executables, so they are NOT recycled), and LRU ties
    (never-hit entries) are broken by arena footprint, evicting the
    entry holding the most workspace first.
    """

    def __init__(self, capacity: int = 64, *, telemetry=None,
                 arena: Optional[Arena] = None):
        assert capacity >= 1
        self.capacity = capacity
        self.arena = arena
        self.hits = 0        # guarded-by: _lock
        self.misses = 0      # guarded-by: _lock
        self.evictions = 0   # guarded-by: _lock
        # Lifecycle events (insert/evict/specialize/load) go to the
        # engine's telemetry ring buffer; the shared NULL handle makes a
        # bare PlanCache() emit-free without branching at call sites.
        self.telemetry = (telemetry if telemetry is not None
                          else telemetry_mod.NULL)
        self._lock = threading.Lock()
        self._stamp = itertools.count(1)
        self._entries: "OrderedDict[PlanKey, CacheEntry]" = OrderedDict()  # guarded-by: _lock

    # -- lookup ------------------------------------------------------------
    def get(self, key: PlanKey) -> Optional[CacheEntry]:
        """LRU lookup; counts a hit or a miss."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            entry.last_used = next(self._stamp)
            self.hits += 1
            return entry

    def peek(self, key: PlanKey) -> Optional[CacheEntry]:
        """Lookup WITHOUT counting a hit/miss or touching LRU order.

        For advisory reads — the serving layer's deadline admission asks
        "is this plan hot?" before dispatch, and that question must not
        perturb the hit-rate counters or the eviction order the real
        ``get`` on the same request is about to establish."""
        with self._lock:
            return self._entries.get(key)

    def insert(self, plan: SpgemmPlan) -> CacheEntry:
        """Insert a fresh plan (evicting LRU entries over capacity)."""
        with self._lock:
            return self._insert_locked(plan)

    def _footprint(self, entry: CacheEntry) -> int:
        """Arena bytes this entry answers for: outstanding (in-flight)
        lease bytes plus the lease its specialized plan would take."""
        spec = entry.plan.workspace_spec()
        return (sum(l.spec.nbytes for l in entry.leases if l.active)
                + (spec.nbytes if spec is not None else 0))

    def _release_entry_locked(self, entry: CacheEntry) -> None:
        """Drop an evicted entry's compiled artifacts and forfeit its
        outstanding arena leases (accounting only — the buffers may be
        inside still-running executables and are never recycled)."""
        entry.executable = None
        if self.arena is not None:
            for lease in entry.leases:
                self.arena.forfeit(lease)
        entry.leases.clear()

    def _evict_one_locked(self, protect: Optional[PlanKey] = None) -> None:
        """Evict the LRU victim; ties (same ``last_used`` — in practice
        never-hit entries, all stamped 0) go to the largest arena
        footprint, so capacity pressure frees the most workspace.
        ``protect`` (the key just inserted) is never the victim."""
        key = min((k for k in self._entries if k != protect),
                  key=lambda k: (self._entries[k].last_used,
                                 -self._footprint(self._entries[k])))
        evicted = self._entries.pop(key)
        self._release_entry_locked(evicted)
        self.evictions += 1
        self.telemetry.event("plan_evict", plan=plan_label(evicted.plan))

    def _insert_locked(self, plan: SpgemmPlan,
                       stamp: Optional[int] = None) -> CacheEntry:
        """Insert-and-evict body; caller holds ``self._lock``.

        Insertion counts as use (matching the OrderedDict LRU order this
        cache always had); ``stamp`` lets a batch insert (:meth:`load`)
        give every loaded plan ONE shared stamp, so loaded-but-unused
        plans are genuine LRU ties and the footprint tie-break decides
        among them."""
        entry = CacheEntry(plan=plan)
        entry.last_used = stamp if stamp is not None else next(self._stamp)
        self._entries[plan.signature] = entry
        self._entries.move_to_end(plan.signature)
        self.telemetry.event("plan_insert", plan=plan_label(plan))
        while len(self._entries) > self.capacity:
            self._evict_one_locked(protect=plan.signature)
        return entry

    def evict(self, key: PlanKey) -> bool:
        """Explicitly evict one entry, forfeiting its arena leases.
        Returns whether the key was present."""
        with self._lock:
            entry = self._entries.pop(key, None)
            if entry is None:
                return False
            self._release_entry_locked(entry)
            self.evictions += 1
        self.telemetry.event("plan_evict", plan=plan_label(entry.plan))
        return True

    def specialize(self, entry: CacheEntry, plan: SpgemmPlan) -> None:
        """Swap in a (re)specialized plan; stale executables are dropped
        (their static capacities no longer match)."""
        with self._lock:
            entry.plan = plan
            entry.executable = None
        self.telemetry.event("plan_specialize", plan=plan_label(plan),
                             prod_bucket=plan.prod_bucket,
                             nnz_bucket=plan.nnz_bucket)

    def update_policy(self, entry: CacheEntry, state: "PolicyState") -> None:
        """Swap in updated adaptive-policy state WITHOUT dropping the
        executable: policy fields never enter a trace (no static shape
        reads them), so the compiled steady state stays valid — this is
        what lets the engine fold telemetry in on every hot finalize."""
        with self._lock:
            entry.plan = entry.plan.with_policy(state)

    # -- persistence --------------------------------------------------------
    def dump(self, path: str) -> int:
        """Serialize every cached plan's learned state to JSON.

        What persists is exactly what a fresh process cannot rederive
        without traffic: the capacity buckets, hash launch schedules, and
        shard specs (progressive-allocation state).  Executables are NOT
        persisted — they rebuild on first use, so a loaded cache costs one
        trace per plan instead of a cold steps call plus regrows.
        Returns the number of entries written.
        """
        plans = [entry.plan for _, entry in self.items()]
        payload = {
            "version": _DUMP_VERSION,
            "plans": [_plan_to_json(p) for p in plans],
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
        return len(plans)

    def load(self, path: str) -> int:
        """Prewarm the cache from a :meth:`dump` file (cross-process
        plan-cache).  Loaded plans merge monotonically into any existing
        same-signature entries (buckets/schedules/specs only grow).

        Accepts any version in ``_LOADABLE_VERSIONS``: v1 payloads (and
        hand-edited ones) may carry hash schedules learned before row
        packing / fusion landed, whose sym buckets were never aligned to
        ``rows_per_block`` — such a schedule would satisfy ``admits_fused``
        (the sizes fit) yet hand the fused kernels a sub-pack geometry the
        packed grid can't be carved from, so every loaded plan's schedule
        is re-aligned (pow-2 sanitized + pack-floored, monotone: buckets
        only grow) before it enters the cache.  Returns the number of
        plans loaded."""
        with open(path) as f:
            payload = json.load(f)
        if payload.get("version") not in _LOADABLE_VERSIONS:
            raise ValueError(
                f"plan-cache dump version {payload.get('version')!r} not in "
                f"{_LOADABLE_VERSIONS}")
        plans = [_align_schedule_for_packing(_plan_from_json(blob))
                 for blob in payload["plans"]]
        # One critical section for the whole merge: a concurrent
        # overflow-grow must not interleave between our read of an
        # entry's plan and the write-back (lost update would shrink it).
        with self._lock:
            batch_stamp = next(self._stamp)   # loaded plans tie on LRU age
            for plan in plans:
                existing = self._entries.get(plan.signature)
                if existing is None:
                    self._insert_locked(plan, stamp=batch_stamp)
                    continue
                merged = existing.plan
                if plan.prod_bucket is not None:
                    merged = merged.with_capacities(
                        max(merged.prod_bucket or 0, plan.prod_bucket),
                        max(merged.nnz_bucket or 0, plan.nnz_bucket))
                if plan.hash_schedule is not None:
                    sched = plan.hash_schedule
                    if merged.hash_schedule is not None:
                        sched = sched.union(merged.hash_schedule)
                    merged = merged.with_hash_schedule(sched)
                if plan.shard_spec is not None:
                    spec = (merged.shard_spec.union(plan.shard_spec)
                            if merged.shard_spec is not None
                            else plan.shard_spec)
                    merged = merged.with_shard_spec(spec)
                if plan.policy is not None:
                    state = (merged.policy.union(plan.policy)
                             if merged.policy is not None else plan.policy)
                    merged = merged.with_policy(state)
                # A no-op merge must NOT drop the live executable: a warm
                # engine loading an equal-or-smaller dump keeps its
                # zero-retrace steady state.  Policy state never enters a
                # trace, so a policy-only difference keeps it too.
                if merged != existing.plan:
                    policy_only = (merged.with_policy(existing.plan.policy)
                                   == existing.plan)
                    existing.plan = merged
                    if not policy_only:
                        existing.executable = None
        self.telemetry.event("plan_cache_load", path=str(path),
                             n_plans=len(plans))
        return len(plans)

    # -- introspection ------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._entries

    def items(self) -> Iterable[Tuple[PlanKey, CacheEntry]]:
        with self._lock:
            return list(self._entries.items())

    def clear(self) -> None:
        with self._lock:
            for entry in self._entries.values():
                self._release_entry_locked(entry)   # no lease leaks
            self._entries.clear()


# -- JSON (de)serialization helpers -----------------------------------------

def _plan_to_json(p: SpgemmPlan) -> dict:
    blob = {
        "a_sig": dataclasses.asdict(p.a_sig),
        "b_sig": dataclasses.asdict(p.b_sig),
        "config": dataclasses.asdict(p.config),
        "prod_bucket": p.prod_bucket,
        "nnz_bucket": p.nnz_bucket,
        "hash_schedule": (dataclasses.asdict(p.hash_schedule)
                          if p.hash_schedule is not None else None),
        "shard_spec": (dataclasses.asdict(p.shard_spec)
                       if p.shard_spec is not None else None),
        "policy": (dataclasses.asdict(p.policy)
                   if p.policy is not None else None),
    }
    return blob


def _plan_from_json(blob: dict) -> SpgemmPlan:
    plan = make_plan(MatrixSig(**blob["a_sig"]), MatrixSig(**blob["b_sig"]),
                     SpgemmConfig(**blob["config"]))
    if blob.get("prod_bucket") is not None:
        plan = plan.with_capacities(blob["prod_bucket"], blob["nnz_bucket"])
    hs = blob.get("hash_schedule")
    if hs is not None:
        if "fall_prod_bucket" in hs:                  # v3
            fall = hs["fall_prod_bucket"]
        else:  # v1/v2 kept per-phase capacities; the shared bucket is
               # their max (monotone: everything admitted stays admitted)
            fall = max(hs["sym_fall_prod_bucket"],
                       hs["num_fall_prod_bucket"])
        plan = plan.with_hash_schedule(HashSchedule(
            sym_row_buckets=tuple(hs["sym_row_buckets"]),
            num_row_buckets=tuple(hs["num_row_buckets"]),
            fall_prod_bucket=int(fall)))
    ss = blob.get("shard_spec")
    if ss is not None:
        plan = plan.with_shard_spec(ShardSpec(
            bounds=tuple(ss["bounds"]),
            row_buckets=tuple(ss["row_buckets"]),
            cap_buckets=tuple(ss["cap_buckets"])))
    pol = blob.get("policy")            # absent from v1 dumps
    if pol is not None:
        for key in ("sym_max", "num_max"):
            if pol.get(key) is not None:
                pol[key] = tuple(pol[key])   # JSON lists -> hashable state
        plan = plan.with_policy(PolicyState(**pol))
    return plan


def _align_schedule_for_packing(plan: SpgemmPlan) -> SpgemmPlan:
    """Re-derive pack alignment for a LOADED plan's hash schedule.

    A schedule persisted before row packing / fusion landed (v1 dumps) —
    or hand-edited JSON — can hold sym buckets that are not pow-2, or
    smaller than a rung's ``rows_per_block``; ``admits_fused`` would
    still pass (the observed sizes fit) while the packed kernels (fused
    or standalone symbolic — both pack since the symbolic kernel gained
    sub-table batching) require pow-2 buckets carved into whole
    ``pack``-row grid steps.
    Alignment is monotone (buckets only grow), so every previously-
    admitted request stays admitted.
    """
    sched = plan.hash_schedule
    if sched is None or plan.config.method != "hash":
        return plan
    packs = plan.sym_ladder.rows_per_block
    packed = plan.config.row_packing

    def aligned(buckets, rung_packs):
        out = []
        for b, cap in enumerate(buckets):
            if cap:
                lo = (rung_packs[b]
                      if rung_packs is not None and b < len(rung_packs)
                      else 1)
                cap = next_bucket(int(cap), minimum=max(int(lo), 1))
            out.append(int(cap))
        return tuple(out)

    aligned_sched = HashSchedule(
        sym_row_buckets=aligned(sched.sym_row_buckets,
                                packs if packed else None),
        num_row_buckets=aligned(sched.num_row_buckets, None),
        fall_prod_bucket=sched.fall_prod_bucket)
    if aligned_sched == sched:
        return plan
    return plan.with_hash_schedule(aligned_sched)
