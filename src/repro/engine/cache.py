"""LRU plan cache — the recompile<->cudaMalloc analog of OpSparse §5.4.

The paper amortizes allocation by overlapping ``cudaMalloc`` with kernel
execution; the JAX port's dominant repeat cost is tracing + XLA
compilation.  The cache holds, per plan signature, the specialized
:class:`~repro.engine.plan.SpgemmPlan` AND the jitted steady-state
executable built for it, so a repeat shape bucket skips tracing entirely.

Hit/miss/eviction counters are first-class (the acceptance benchmark
reports the hit rate); eviction drops the executable reference, which
releases the underlying compiled program once JAX's own caches let go.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, Iterable, Optional, Tuple

from .plan import PlanKey, SpgemmPlan
from .stats import PlanStats


@dataclasses.dataclass
class CacheEntry:
    """A cached plan plus its compiled artifacts and telemetry."""

    plan: SpgemmPlan
    executable: Optional[Callable] = None   # jitted hot path (ESC or hash)
    stats: PlanStats = dataclasses.field(default_factory=PlanStats)


class PlanCache:
    """Thread-safe LRU cache keyed by plan signature."""

    def __init__(self, capacity: int = 64):
        assert capacity >= 1
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._lock = threading.Lock()
        self._entries: "OrderedDict[PlanKey, CacheEntry]" = OrderedDict()

    # -- lookup ------------------------------------------------------------
    def get(self, key: PlanKey) -> Optional[CacheEntry]:
        """LRU lookup; counts a hit or a miss."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return entry

    def insert(self, plan: SpgemmPlan) -> CacheEntry:
        """Insert a fresh plan (evicting LRU entries over capacity)."""
        entry = CacheEntry(plan=plan)
        with self._lock:
            self._entries[plan.signature] = entry
            self._entries.move_to_end(plan.signature)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
        return entry

    def specialize(self, entry: CacheEntry, plan: SpgemmPlan) -> None:
        """Swap in a (re)specialized plan; stale executables are dropped
        (their static capacities no longer match)."""
        with self._lock:
            entry.plan = plan
            entry.executable = None

    # -- introspection ------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._entries

    def items(self) -> Iterable[Tuple[PlanKey, CacheEntry]]:
        with self._lock:
            return list(self._entries.items())

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
