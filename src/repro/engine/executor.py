"""Batched, plan-cached SpGEMM executor — the engine behind ``spgemm()``.

This module owns BOTH execution paths for the OpSparse two-phase flow
(paper Fig. 2):

``_execute_steps``
    The faithful host-orchestrated six-step pipeline (setup, sym-bin,
    symbolic, alloc, num-bin, numeric) moved here from ``core/spgemm.py``.
    It serves cold calls (capacity buckets unknown), the hash method
    (whose §5.5 launch schedule is a host decision), and ``timing`` runs.

``_build_hot_executable``
    The steady-state path: ONE jitted closure per specialized plan.  With
    the product/nnz buckets already learned there is nothing left for the
    host to decide mid-flight, so the paper's two mandatory host syncs
    collapse into a single post-dispatch read that merely *verifies* the
    buckets — the recompile/allocation analog of §5.4's alloc/exec overlap.

The :class:`SpgemmEngine` streams requests through a plan cache
(``cache.py``): requests are grouped by plan signature, operands are padded
to the signature's pow-2 storage buckets (so every group member reuses one
executable), and the drain loop is double-buffered — request ``k+1`` is
planned and dispatched on the host while request ``k`` still executes on
device, and only then is ``k`` finalized (its one host sync).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import esc
from repro.core.analysis import exclusive_sum_in_place, nprod_into_rpt
from repro.core.binning import bin_rows, bin_rows_for_ladder
from repro.core.csr import CSR
from repro.core.spgemm import SpgemmConfig, SpgemmResult, next_bucket

from . import stats as stats_mod
from .cache import CacheEntry, PlanCache
from .plan import MatrixSig, SpgemmPlan, plan as make_plan
from .stats import EngineStats

_exclusive_sum = jax.jit(exclusive_sum_in_place, donate_argnums=0)


class StepTimer:
    """Per-step wall-clock instrumentation (blocks only when enabled)."""

    def __init__(self, enabled: bool):
        self.enabled = enabled
        self.timings: Dict[str, float] = {}

    def measure(self, name: str, value):
        """Block on `value` and charge the elapsed time to `name`."""
        if self.enabled:
            t0 = time.perf_counter()
            jax.block_until_ready(value)
            self.timings[name] = self.timings.get(name, 0.0) + (
                time.perf_counter() - t0)
        return value


# ---------------------------------------------------------------------------
# Path 1: the faithful six-step host-orchestrated flow (paper Fig. 2).
# ---------------------------------------------------------------------------

def _execute_steps(A: CSR, B: CSR, plan: SpgemmPlan,
                   timer: StepTimer):
    """Cold / hash / timing path.  Returns (result, prod_cap, nnz_cap).

    Identical math to the pre-engine ``core.spgemm`` flow, except the
    capacity buckets are floored at the plan's learned buckets so repeat
    shapes keep hitting the same per-kernel executables.
    """
    config = plan.config
    m = A.nrows
    sym_ladder, num_ladder = plan.sym_ladder, plan.num_ladder

    # ---- step1: setup -----------------------------------------------------
    rpt_buf = nprod_into_rpt(A, B)               # n_prod lives in C.rpt (§5.3)
    timer.measure("setup", rpt_buf)
    nprod = rpt_buf[:m]
    total_nprod = int(jnp.sum(nprod))            # host sync #1 (sizes launches)

    # ---- step2: symbolic binning -------------------------------------------
    sym_binning = bin_rows_for_ladder(nprod, sym_ladder)
    timer.measure("symbolic_binning", sym_binning.bins)

    prod_capacity = max(plan.prod_bucket or 0,
                        next_bucket(max(total_nprod, 1)))

    # ---- step3: symbolic ----------------------------------------------------
    if config.method == "hash":
        from repro.kernels import spgemm_hash
        nnz_buf = spgemm_hash.symbolic_binned(
            A, B, sym_binning, sym_ladder,
            prod_capacity=prod_capacity,
            single_access=config.hash_single_access,
            interpret=config.interpret)
    else:
        nnz_buf = esc.symbolic(A, B, prod_capacity=prod_capacity)
    timer.measure("symbolic", nnz_buf)

    # ---- step4: alloc -------------------------------------------------------
    nnz = nnz_buf[:m]
    # Numeric binning is dispatched BEFORE the host reads total_nnz: the
    # launch-early / allocate-later ordering of §5.4.
    num_binning = bin_rows_for_ladder(nnz, num_ladder)
    total_nnz = int(jnp.sum(nnz))                # host sync #2 (alloc C)
    nnz_capacity = max(plan.nnz_bucket or 0, next_bucket(max(total_nnz, 1)))
    rpt = _exclusive_sum(nnz_buf)                # in-place on the rpt buffer
    timer.measure("alloc", rpt)
    timer.measure("numeric_binning", num_binning.bins)

    # ---- step6: numeric -----------------------------------------------------
    if config.method == "hash":
        from repro.kernels import spgemm_hash
        C = spgemm_hash.numeric_binned(
            A, B, rpt, num_binning, num_ladder,
            prod_capacity=prod_capacity, nnz_capacity=nnz_capacity,
            single_access=config.hash_single_access,
            interpret=config.interpret)
    elif config.fuse_esc:
        C = esc.spgemm_fused(A, B, prod_capacity=prod_capacity,
                             nnz_capacity=nnz_capacity)
    else:
        C = esc.numeric(A, B, rpt, prod_capacity=prod_capacity,
                        nnz_capacity=nnz_capacity)
    timer.measure("numeric", C.val)

    result = SpgemmResult(
        C=C, total_nprod=total_nprod, total_nnz=total_nnz,
        sym_binning=sym_binning, num_binning=num_binning,
        timings=timer.timings)
    return result, prod_capacity, nnz_capacity


# ---------------------------------------------------------------------------
# Path 2: the steady-state jitted executable (one trace per plan).
# ---------------------------------------------------------------------------

def _build_hot_executable(plan: SpgemmPlan) -> Callable:
    """Jit the whole two-phase flow against a specialized plan.

    Every shape is static (the plan's buckets), so the full pipeline —
    setup, both binnings, symbolic, alloc, numeric — fuses into one
    executable with zero mid-flight host syncs.  The totals come back as
    device scalars; the engine's finalize step reads them once to verify
    the buckets still hold (growing them on overflow).
    """
    assert plan.is_specialized and plan.config.method == "esc"
    m = plan.a_sig.nrows
    config = plan.config
    sym_upper = plan.sym_ladder.upper
    sym_nb = plan.sym_ladder.num_bins
    num_upper = plan.num_ladder.upper
    num_nb = plan.num_ladder.num_bins
    prod_cap, nnz_cap = plan.prod_bucket, plan.nnz_bucket
    key = plan.signature

    @jax.jit
    def run(A: CSR, B: CSR):
        stats_mod.record_trace(key)      # fires once per trace (recompile)
        rpt_buf = nprod_into_rpt(A, B)
        nprod = rpt_buf[:m]
        total_nprod = jnp.sum(nprod)
        sym_binning = bin_rows(nprod, upper=sym_upper, num_bins=sym_nb)
        nnz_buf = esc.symbolic(A, B, prod_capacity=prod_cap)
        nnz = nnz_buf[:m]
        num_binning = bin_rows(nnz, upper=num_upper, num_bins=num_nb)
        total_nnz = jnp.sum(nnz)
        rpt = exclusive_sum_in_place(nnz_buf)
        if config.fuse_esc:
            C = esc.spgemm_fused(A, B, prod_capacity=prod_cap,
                                 nnz_capacity=nnz_cap)
        else:
            C = esc.numeric(A, B, rpt, prod_capacity=prod_cap,
                            nnz_capacity=nnz_cap)
        return C, total_nprod, total_nnz, sym_binning, num_binning

    return run


# ---------------------------------------------------------------------------
# Request records.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpgemmRequest:
    """One queued (A, B) product awaiting drain()."""

    uid: int
    A: CSR
    B: CSR
    config: SpgemmConfig


@dataclasses.dataclass
class _Finished:
    """Synchronously-completed dispatch (steps path)."""

    uid: int
    result: SpgemmResult


@dataclasses.dataclass
class _Pending:
    """Asynchronously-dispatched hot-path call awaiting its one host sync."""

    uid: int
    entry: CacheEntry
    plan: SpgemmPlan    # the plan the run was dispatched against: the
                        # entry may be re-specialized while we're in flight
    A: CSR
    B: CSR
    handles: tuple      # (C, total_nprod, total_nnz, sym_binning, num_binning)
    t0: float


_Record = Union[_Finished, _Pending]


class SpgemmEngine:
    """Streaming SpGEMM front-end: plan cache + batched async executor.

    Usage::

        engine = SpgemmEngine()
        r = engine.execute(A, B)                 # synchronous, plan-cached

        engine.submit(A1, B1); engine.submit(A2, B2)
        results = engine.drain()                 # batched, double-buffered

    ``execute`` is what ``repro.core.spgemm`` wraps; ``submit``/``drain``
    is the serving-path API (requests grouped by plan, request k+1 planned
    while request k executes).
    """

    def __init__(self, config: Optional[SpgemmConfig] = None, *,
                 cache_capacity: int = 64):
        self.config = config or SpgemmConfig()
        self.cache = PlanCache(cache_capacity)
        self.stats = EngineStats()
        self._queue: List[SpgemmRequest] = []
        self._uids = itertools.count()

    # -- public API ---------------------------------------------------------
    def execute(self, A: CSR, B: CSR,
                config: Optional[SpgemmConfig] = None) -> SpgemmResult:
        """Plan-then-execute one product (the ``spgemm()`` backend)."""
        rec = self._dispatch(next(self._uids), A, B, config or self.config)
        return self._finalize(rec)

    def prewarm(self, A: CSR, B: CSR,
                config: Optional[SpgemmConfig] = None, *,
                prod_bucket: int, nnz_bucket: int) -> SpgemmPlan:
        """Ahead-of-time plan specialization (no execution).

        Seeds the plan for (A, B)'s signatures with caller-provided
        capacity buckets — Liu & Vinter-style ahead-of-time allocation
        for workloads whose product sizes are known (or bounded) up
        front, e.g. a BFS whose frontiers grow hop over hop.  The first
        real request then goes straight to the jitted hot path instead
        of paying a cold discovery call plus progressive regrows.
        """
        config = config or self.config
        a_sig, b_sig = MatrixSig.of(A), MatrixSig.of(B)
        entry = self.cache.get((a_sig, b_sig, config))
        if entry is None:
            entry = self.cache.insert(make_plan(a_sig, b_sig, config))
        self.cache.specialize(entry, entry.plan.with_capacities(
            max(entry.plan.prod_bucket or 0,
                next_bucket(max(prod_bucket, 1))),
            max(entry.plan.nnz_bucket or 0,
                next_bucket(max(nnz_bucket, 1)))))
        return entry.plan

    def submit(self, A: CSR, B: CSR,
               config: Optional[SpgemmConfig] = None) -> int:
        """Queue a request; returns its uid (resolved by ``drain``)."""
        assert A.ncols == B.nrows, (A.shape, B.shape)
        uid = next(self._uids)
        self._queue.append(SpgemmRequest(uid, A, B, config or self.config))
        return uid

    def drain(self) -> Dict[int, SpgemmResult]:
        """Run all queued requests; returns {uid: result}.

        Requests are grouped by plan signature (group members share one
        executable) and pipelined: dispatch(k+1) happens before
        finalize(k), so host planning overlaps device execution.
        """
        queue, self._queue = self._queue, []
        self.stats.drains += 1
        groups: "OrderedDict[tuple, List[SpgemmRequest]]" = OrderedDict()
        for req in queue:
            key = (MatrixSig.of(req.A), MatrixSig.of(req.B), req.config)
            groups.setdefault(key, []).append(req)

        results: Dict[int, SpgemmResult] = {}
        inflight: Optional[_Record] = None
        for req in itertools.chain.from_iterable(groups.values()):
            rec = self._dispatch(req.uid, req.A, req.B, req.config)
            if inflight is not None:
                if isinstance(inflight, _Pending):
                    self.stats.overlapped += 1   # planned k+1 while k ran
                results[inflight.uid] = self._finalize(inflight)
            inflight = rec
        if inflight is not None:
            results[inflight.uid] = self._finalize(inflight)
        return results

    def report(self) -> str:
        return stats_mod.render(self)

    # -- internals ----------------------------------------------------------
    def _dispatch(self, uid: int, A: CSR, B: CSR,
                  config: SpgemmConfig) -> _Record:
        assert A.ncols == B.nrows, (A.shape, B.shape)
        self.stats.requests += 1
        t0 = time.perf_counter()
        a_sig, b_sig = MatrixSig.of(A), MatrixSig.of(B)
        entry = self.cache.get((a_sig, b_sig, config))
        if entry is None:
            entry = self.cache.insert(make_plan(a_sig, b_sig, config))
        entry.stats.calls += 1

        # Canonicalize operand storage to the signature buckets so every
        # request in the bucket presents identical static shapes.
        A = A.with_capacity(a_sig.cap_bucket)
        B = B.with_capacity(b_sig.cap_bucket)

        plan = entry.plan
        hot_eligible = (plan.is_specialized and config.method == "esc"
                        and not config.timing)
        if not hot_eligible:
            result, prod_cap, nnz_cap = _execute_steps(
                A, B, plan, StepTimer(config.timing))
            if not plan.is_specialized:
                # Progressive allocation: learn the buckets for steady state.
                self.cache.specialize(
                    entry, plan.with_capacities(prod_cap, nnz_cap))
            entry.stats.steps_calls += 1
            entry.stats.time_s += time.perf_counter() - t0
            return _Finished(uid, result)

        if entry.executable is None:
            entry.executable = _build_hot_executable(plan)
        handles = entry.executable(A, B)         # async dispatch, no sync
        entry.stats.hot_calls += 1
        return _Pending(uid, entry, plan, A, B, handles, t0)

    def _finalize(self, rec: _Record) -> SpgemmResult:
        if isinstance(rec, _Finished):
            return rec.result

        C, tnp, tnz, sym_binning, num_binning = rec.handles
        total_nprod, total_nnz = (
            int(x) for x in jax.device_get((tnp, tnz)))  # the ONE host sync
        # Verify against the DISPATCH-TIME plan: a concurrent overflow may
        # have re-specialized the entry with larger buckets than this run
        # actually executed with, and passing its check would return a
        # silently truncated C.
        plan = rec.plan
        if (total_nprod > plan.prod_bucket or total_nnz > plan.nnz_bucket):
            # Bucket overflow (rare: a same-signature request with a larger
            # product).  Grow the buckets and redo via the steps path.
            self.stats.capacity_grows += 1
            rec.entry.stats.capacity_grows += 1
            # NB: an overflowed symbolic phase truncates its expansion, so
            # the hot run's totals are only lower bounds; the steps redo
            # reports the true capacities to respecialize with.  Floor at
            # the entry's CURRENT buckets so a concurrent grow is kept.
            current = rec.entry.plan
            grown = plan.with_capacities(
                max(plan.prod_bucket, current.prod_bucket or 0,
                    next_bucket(max(total_nprod, 1))),
                max(plan.nnz_bucket, current.nnz_bucket or 0,
                    next_bucket(max(total_nnz, 1))))
            result, prod_cap, nnz_cap = _execute_steps(
                rec.A, rec.B, grown, StepTimer(False))
            self.cache.specialize(
                rec.entry, grown.with_capacities(prod_cap, nnz_cap))
            rec.entry.stats.time_s += time.perf_counter() - rec.t0
            return result

        rec.entry.stats.time_s += time.perf_counter() - rec.t0
        return SpgemmResult(
            C=C, total_nprod=total_nprod, total_nnz=total_nnz,
            sym_binning=sym_binning, num_binning=num_binning, timings={})


# ---------------------------------------------------------------------------
# The process-wide default engine behind ``repro.core.spgemm``.
# ---------------------------------------------------------------------------

_DEFAULT: Optional[SpgemmEngine] = None
_DEFAULT_LOCK = threading.Lock()


def default_engine() -> SpgemmEngine:
    """Shared engine serving every ``spgemm()`` call in the process."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = SpgemmEngine()
        return _DEFAULT


def reset_default_engine() -> None:
    """Drop the shared engine (tests that need a cold cache)."""
    global _DEFAULT
    _DEFAULT = None
