"""Batched, plan-cached SpGEMM executor — the engine behind ``spgemm()``.

This module owns BOTH execution paths for the OpSparse two-phase flow
(paper Fig. 2):

``_execute_steps``
    The faithful host-orchestrated six-step pipeline (setup, sym-bin,
    symbolic, alloc, num-bin, numeric) moved here from ``core/spgemm.py``.
    It serves cold calls (capacity buckets / hash launch schedule still
    unknown) and ``timing`` runs.

``_build_hot_executable`` / ``_build_hash_executable``
    The steady-state paths: ONE jitted closure per specialized plan.  With
    the product/nnz buckets — and, for the hash method, the per-rung
    bin-count buckets of the :class:`~repro.engine.plan.HashSchedule` —
    already learned there is nothing left for the host to decide
    mid-flight, so the paper's mandatory host syncs collapse into a single
    post-dispatch read that merely *verifies* the buckets — the
    recompile/allocation analog of §5.4's alloc/exec overlap.  For hash
    plans that read also covers the bin sizes and the fallback rung's
    sub-product totals (still one ``device_get``).

The :class:`SpgemmEngine` streams requests through a plan cache
(``cache.py``): requests are grouped by plan signature, operands are padded
to the signature's pow-2 storage buckets (so every group member reuses one
executable), and the drain loop keeps a bounded window of dispatches in
flight — request ``k+1`` is planned and dispatched on the host while
earlier requests still execute on device — finalizing pending records in
COMPLETION order (whichever device work finishes first gets its one host
sync first; ``drain_ordered=True`` restores dispatch-order finalize).

``shards=N`` fans each request out into flop-balanced row-block
sub-dispatches of A (``partition.py``) that reuse the same plan machinery,
merged back by a per-plan jitted concatenation.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import OrderedDict
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import esc
from repro.core.analysis import (estimate_result, exclusive_sum_in_place,
                                 nprod_into_rpt, row_flops)
from repro.core.binning import bin_rows, bin_rows_for_ladder
from repro.core.csr import CSR
from repro.core.spgemm import (AUTO_SHARDS, SpgemmConfig, SpgemmResult,
                               next_bucket)
from repro.core.faults import FaultPlan, InjectedFault, resolve_faults
from repro.core.workspace import (Arena, ArenaPressureError, Lease,
                                  default_arena)
from repro.kernels import spgemm_hash
from repro.launch.mesh import data_axis_devices

from . import autotune, stats as stats_mod
from .autotune import AdaptivePolicy, MemoryGovernor, PolicyState
from .cache import CacheEntry, PlanCache
from .partition import ShardSpec, plan_shards, shard_devices
from .plan import HashSchedule, MatrixSig, SpgemmPlan, plan as make_plan
from .stats import EngineStats
from .telemetry import Span, Telemetry, resolve_telemetry

_exclusive_sum = jax.jit(exclusive_sum_in_place, donate_argnums=0)

# Capacity buckets (product expansion / C storage) get a smaller margin:
# it only moves the learned pow-2 bucket when the observed total sits in
# the top fifth of one, exactly where same-signature jitter would
# otherwise flip buckets call over call (sharded sub-problems halve the
# totals, putting them near boundaries far more often than whole
# matrices).  Elsewhere it is absorbed by the pow-2 rounding for free.
_CAPACITY_HEADROOM = 1.25


class StepTimer:
    """Per-step wall-clock instrumentation (blocks only when enabled).

    With an ENABLED ``tracer`` each measured step also emits a telemetry
    span (nested under the tracer's current ``with``-span — the cold
    ``cold_steps`` span in practice), giving the trace per-kernel-phase
    attribution on exactly the paths that already host-sync.  The
    ``timings`` dict keeps its historical block-time-only semantics.
    """

    def __init__(self, enabled: bool, tracer: Optional[Telemetry] = None,
                 uid: Optional[int] = None):
        self.tracer = tracer if (tracer is not None
                                 and tracer.enabled) else None
        self.enabled = enabled or self.tracer is not None
        self.uid = uid
        self.timings: Dict[str, float] = {}

    def measure(self, name: str, value):
        """Block on `value` and charge the elapsed time to `name`."""
        if self.enabled:
            span = (self.tracer.start_span(name, uid=self.uid)
                    if self.tracer is not None else None)
            t0 = time.perf_counter()
            jax.block_until_ready(value)
            self.timings[name] = self.timings.get(name, 0.0) + (
                time.perf_counter() - t0)
            if span is not None:
                self.tracer.end_span(span)
        return value


# ---------------------------------------------------------------------------
# Path 1: the faithful six-step host-orchestrated flow (paper Fig. 2).
# ---------------------------------------------------------------------------

def _floor_schedule(row_buckets, fall_cap, plan_buckets, plan_fall):
    """Floor a freshly-derived phase schedule at the plan's learned one so
    repeat shapes keep hitting the same per-kernel executables (and the
    schedule only ever grows)."""
    if plan_buckets is None:
        return row_buckets, fall_cap
    return (tuple(max(a, b) for a, b in zip(row_buckets, plan_buckets)),
            max(fall_cap, plan_fall))


def _execute_steps(A: CSR, B: CSR, plan: SpgemmPlan,
                   timer: StepTimer, *, headroom: float = 2.0):
    """Cold / timing path.  Returns (result, prod_cap, nnz_cap, hash_sched).

    Identical math to the pre-engine ``core.spgemm`` flow, except the
    capacity buckets are floored at the plan's learned buckets so repeat
    shapes keep hitting the same per-kernel executables.  For the hash
    method each phase derives its launch schedule ONCE (``host_schedule``,
    with headroom, floored at the plan's), runs the schedule-driven
    kernels with it, and the combined :class:`HashSchedule` is returned
    for the caller to specialize the plan with (``None`` for ESC).

    ``headroom`` over-provisions the learned bin-count buckets so
    steady-state bin-size jitter stays inside the schedule: padding rows
    are masked grid steps, far cheaper than the steps-redo + recompile an
    overflow costs (the §5.1/§5.6 memory-vs-retrace trade-off).  It is no
    longer a fixed 2x: the engine passes the plan's adaptive-policy value
    (``engine/autotune``) — grown after overflows, shrunk on stable
    streams.
    """
    config = plan.config
    m = A.nrows
    sym_ladder, num_ladder = plan.sym_ladder, plan.num_ladder
    sched = plan.hash_schedule

    # ---- step1: setup -----------------------------------------------------
    rpt_buf = nprod_into_rpt(A, B)               # n_prod lives in C.rpt (§5.3)
    timer.measure("setup", rpt_buf)
    nprod = rpt_buf[:m]
    total_nprod = int(jnp.sum(nprod))            # host sync #1 (sizes launches)

    # ---- step2: symbolic binning -------------------------------------------
    sym_binning = bin_rows_for_ladder(nprod, sym_ladder)
    timer.measure("symbolic_binning", sym_binning.bins)

    prod_capacity = max(plan.prod_bucket or 0,
                        next_bucket(max(int(total_nprod
                                            * _CAPACITY_HEADROOM), 1)))

    # ---- step3: symbolic ----------------------------------------------------
    sym_buckets = sym_fall = None
    if config.method == "hash":
        # Packed configs need pack-aligned sym buckets (the packed kernels
        # batch rows_per_block rows per grid step); learning them aligned
        # here keeps every later union/floor aligned too.  The standalone
        # symbolic kernel packs just like the fused one, so the alignment
        # is needed whether or not the numeric phase fuses.
        sym_packs = (sym_ladder.rows_per_block
                     if config.row_packing else None)
        sym_buckets, sym_fall = _floor_schedule(
            *spgemm_hash.host_schedule(A, B, sym_binning, sym_ladder,
                                       headroom=headroom,
                                       packs=sym_packs),
            sched.sym_row_buckets if sched else None,
            sched.fall_prod_bucket if sched else 0)
        nnz_buf, _, _ = spgemm_hash.symbolic_scheduled(
            A, B, sym_binning, sym_ladder,
            row_buckets=sym_buckets, fallback_prod_capacity=sym_fall,
            single_access=config.hash_single_access,
            interpret=config.interpret, row_packing=config.row_packing)
    else:
        nnz_buf = esc.symbolic(A, B, prod_capacity=prod_capacity)
    timer.measure("symbolic", nnz_buf)

    # ---- step4: alloc -------------------------------------------------------
    nnz = nnz_buf[:m]
    # Numeric binning is dispatched BEFORE the host reads total_nnz: the
    # launch-early / allocate-later ordering of §5.4.
    num_binning = bin_rows_for_ladder(nnz, num_ladder)
    total_nnz = int(jnp.sum(nnz))                # host sync #2 (alloc C)
    nnz_capacity = max(plan.nnz_bucket or 0,
                       next_bucket(max(int(total_nnz
                                           * _CAPACITY_HEADROOM), 1)))
    rpt = _exclusive_sum(nnz_buf)                # in-place on the rpt buffer
    timer.measure("alloc", rpt)
    timer.measure("numeric_binning", num_binning.bins)

    # ---- step6: numeric -----------------------------------------------------
    hash_sched = None
    if config.method == "hash":
        num_buckets, num_fall = _floor_schedule(
            *spgemm_hash.host_schedule(A, B, num_binning, num_ladder,
                                       headroom=headroom),
            sched.num_row_buckets if sched else None,
            sched.fall_prod_bucket if sched else 0)
        # Both phases share ONE fallback expansion capacity (one arena
        # bucket per plan): each phase runs with the shared max.
        fall = max(sym_fall, num_fall)
        C, _, _ = spgemm_hash.numeric_scheduled(
            A, B, rpt, num_binning, num_ladder,
            row_buckets=num_buckets, nnz_capacity=nnz_capacity,
            fallback_prod_capacity=fall,
            single_access=config.hash_single_access,
            interpret=config.interpret)
        hash_sched = HashSchedule(sym_buckets, num_buckets, fall)
    elif config.fuse_esc:
        C = esc.spgemm_fused(A, B, prod_capacity=prod_capacity,
                             nnz_capacity=nnz_capacity)
    else:
        C = esc.numeric(A, B, rpt, prod_capacity=prod_capacity,
                        nnz_capacity=nnz_capacity)
    timer.measure("numeric", C.val)

    result = SpgemmResult(
        C=C, total_nprod=total_nprod, total_nnz=total_nnz,
        sym_binning=sym_binning, num_binning=num_binning,
        timings=timer.timings)
    return result, prod_capacity, nnz_capacity, hash_sched


# ---------------------------------------------------------------------------
# Path 2: the steady-state jitted executable (one trace per plan).
# ---------------------------------------------------------------------------

def _donate_workspace(body: Callable) -> Callable:
    """Wrap a steady-state pipeline so it carries an arena lease through
    the trace: the leased buffers are DONATED into the executable and
    returned as outputs, so XLA aliases the outputs onto the donated HBM
    blocks — the same physical workspace serves request after request
    instead of each dispatch allocating fresh expansion buffers (§5.4's
    alloc/exec overlap, generalized arena-wide).  The engine rebinds the
    plan's lease to the RETURNED arrays at finalize (the donated inputs
    are consumed and must not be touched again)."""
    @partial(jax.jit, donate_argnums=(2, 3))
    def run(A: CSR, B: CSR, ws_i32: jax.Array, ws_val: jax.Array):
        return body(A, B) + (ws_i32, ws_val)
    return run


def _finish_executable(plan: SpgemmPlan, body: Callable) -> Callable:
    """Jit a builder's pipeline body, threading the arena lease through
    when the plan holds one (``workspace_spec() is not None``)."""
    if plan.workspace_spec() is not None:
        return _donate_workspace(body)
    return jax.jit(body)


def _build_hot_executable(plan: SpgemmPlan) -> Callable:
    """Jit the whole two-phase flow against a specialized plan.

    Every shape is static (the plan's buckets), so the full pipeline —
    setup, both binnings, symbolic, alloc, numeric — fuses into one
    executable with zero mid-flight host syncs.  The totals come back as
    device scalars; the engine's finalize step reads them once to verify
    the buckets still hold (growing them on overflow).
    """
    assert plan.is_specialized and plan.config.method == "esc"
    m = plan.a_sig.nrows
    config = plan.config
    sym_upper = plan.sym_ladder.upper
    sym_nb = plan.sym_ladder.num_bins
    num_upper = plan.num_ladder.upper
    num_nb = plan.num_ladder.num_bins
    prod_cap, nnz_cap = plan.prod_bucket, plan.nnz_bucket
    key = plan.signature

    def body(A: CSR, B: CSR):
        stats_mod.record_trace(key)      # fires once per trace (recompile)
        rpt_buf = nprod_into_rpt(A, B)
        nprod = rpt_buf[:m]
        total_nprod = jnp.sum(nprod)
        sym_binning = bin_rows(nprod, upper=sym_upper, num_bins=sym_nb)
        nnz_buf = esc.symbolic(A, B, prod_capacity=prod_cap)
        nnz = nnz_buf[:m]
        num_binning = bin_rows(nnz, upper=num_upper, num_bins=num_nb)
        total_nnz = jnp.sum(nnz)
        rpt = exclusive_sum_in_place(nnz_buf)
        if config.fuse_esc:
            C = esc.spgemm_fused(A, B, prod_capacity=prod_cap,
                                 nnz_capacity=nnz_cap)
        else:
            C = esc.numeric(A, B, rpt, prod_capacity=prod_cap,
                            nnz_capacity=nnz_cap)
        return C, total_nprod, total_nnz, sym_binning, num_binning

    return _finish_executable(plan, body)


def _build_hash_executable(plan: SpgemmPlan) -> Callable:
    """Jit the whole hash pipeline against a specialized plan (§5.1–§5.5).

    The plan's :class:`HashSchedule` makes the per-rung launch loop a
    static schedule (fixed-capacity ``pallas_call`` per populated rung,
    largest rung first), so the two binnings, every hash kernel, and the
    ESC fallback rung all trace into ONE executable — the hash method's
    zero-retrace steady state.  The returned device scalars (totals, bin
    sizes via the binnings, fallback sub-products) let finalize verify
    the whole schedule in its single host sync.
    """
    assert plan.is_specialized and plan.config.method == "hash"
    m = plan.a_sig.nrows
    config = plan.config
    sym_ladder, num_ladder = plan.sym_ladder, plan.num_ladder
    sched = plan.hash_schedule
    nnz_cap = plan.nnz_bucket
    key = plan.signature

    def body(A: CSR, B: CSR):
        stats_mod.record_trace(key)      # fires once per trace (recompile)
        rpt_buf = nprod_into_rpt(A, B)
        nprod = rpt_buf[:m]
        total_nprod = jnp.sum(nprod)
        sym_binning = bin_rows(nprod, upper=sym_ladder.upper,
                               num_bins=sym_ladder.num_bins)
        nnz_buf, sym_fall_prod, _ = spgemm_hash.symbolic_scheduled(
            A, B, sym_binning, sym_ladder,
            row_buckets=sched.sym_row_buckets,
            fallback_prod_capacity=sched.fall_prod_bucket,
            single_access=config.hash_single_access,
            interpret=config.interpret)
        nnz = nnz_buf[:m]
        num_binning = bin_rows(nnz, upper=num_ladder.upper,
                               num_bins=num_ladder.num_bins)
        total_nnz = jnp.sum(nnz)
        rpt = exclusive_sum_in_place(nnz_buf)
        # Both phases expand into the SAME shared fallback capacity (one
        # arena bucket, one traced expansion shape per plan).
        C, num_fall_prod, _ = spgemm_hash.numeric_scheduled(
            A, B, rpt, num_binning, num_ladder,
            row_buckets=sched.num_row_buckets,
            nnz_capacity=nnz_cap,
            fallback_prod_capacity=sched.fall_prod_bucket,
            single_access=config.hash_single_access,
            interpret=config.interpret)
        return (C, total_nprod, total_nnz, sym_binning, num_binning,
                sym_fall_prod, num_fall_prod)

    return _finish_executable(plan, body)


def _build_fused_hash_executable(plan: SpgemmPlan) -> Callable:
    """Jit the FUSED hash pipeline against a specialized plan.

    ``fuse_numeric`` steady state: one n_prod binning (symbolic ladder),
    one table build per row (``spgemm_hash.fused_scheduled``) emitting
    nnz AND accumulated values, so the paper's symbolic/numeric table
    double-build collapses to a single probe pass — roughly half the
    per-row table transactions of the two-pass executable (the cold
    steps path, which stays the parity oracle).  The finalize sync
    verifies only the sym schedule + fallback product + nnz bucket
    (there is no numeric binning to check).
    """
    assert (plan.is_specialized and plan.config.method == "hash"
            and plan.config.fuse_numeric)
    m = plan.a_sig.nrows
    config = plan.config
    sym_ladder, num_ladder = plan.sym_ladder, plan.num_ladder
    sched = plan.hash_schedule
    nnz_cap = plan.nnz_bucket
    key = plan.signature

    def body(A: CSR, B: CSR):
        stats_mod.record_trace(key)      # fires once per trace (recompile)
        rpt_buf = nprod_into_rpt(A, B)
        nprod = rpt_buf[:m]
        total_nprod = jnp.sum(nprod)
        sym_binning = bin_rows(nprod, upper=sym_ladder.upper,
                               num_bins=sym_ladder.num_bins)
        C, nnz, sym_fall_prod, _ = spgemm_hash.fused_scheduled(
            A, B, sym_binning, sym_ladder,
            row_buckets=sched.sym_row_buckets,
            nnz_capacity=nnz_cap,
            fallback_prod_capacity=sched.fall_prod_bucket,
            single_access=config.hash_single_access,
            interpret=config.interpret,
            row_packing=config.row_packing)
        total_nnz = jnp.sum(nnz)
        # No numeric phase runs, but the n_nz binning stays part of the
        # result so fused steady-state calls report the same telemetry
        # shape as cold calls (it's a cheap histogram, not a probe pass).
        num_binning = bin_rows(nnz, upper=num_ladder.upper,
                               num_bins=num_ladder.num_bins)
        return (C, total_nprod, total_nnz, sym_binning, num_binning,
                sym_fall_prod)

    return _finish_executable(plan, body)


def _build_merge_executable(spec: ShardSpec, m: int, n: int) -> Callable:
    """Jit the per-shard CSR concatenation for a sharded plan's partition.

    Row-block sub-products are disjoint in row space, so the merged C is a
    pure concatenation: shard row pointers rebased by the running nnz
    offsets (on device — no host math touches the arrays) and each shard's
    packed entries scattered at its offset.  Shapes are static (the real
    row counts come from the spec's pinned bounds; storage from the shard
    results' capacities), so one trace serves the steady state; a shard
    plan's nnz-bucket growth changes an input shape and retraces once.
    """
    real_rows = tuple(spec.rows(s) for s in range(spec.n_shards))
    key = ("merge", spec.bounds, m, n)

    @jax.jit
    def run(parts):
        stats_mod.record_trace(key)      # fires once per trace (recompile)
        nnzs = jnp.stack([C.rpt[r] for C, r in zip(parts, real_rows)])
        offs = jnp.concatenate(
            [jnp.zeros(1, jnp.int32),
             jnp.cumsum(nnzs).astype(jnp.int32)])
        rpt = jnp.concatenate(
            [C.rpt[:r] + offs[i]
             for i, (C, r) in enumerate(zip(parts, real_rows))]
            + [offs[-1:]])
        out_cap = sum(C.capacity for C in parts)
        col = jnp.zeros(out_cap, jnp.int32)
        val = jnp.zeros(out_cap, parts[0].val.dtype)
        for i, C in enumerate(parts):
            idx = jnp.arange(C.capacity, dtype=jnp.int32)
            tgt = jnp.where(idx < nnzs[i], offs[i] + idx, out_cap)  # drop pad
            col = col.at[tgt].set(C.col, mode="drop")
            val = val.at[tgt].set(C.val, mode="drop")
        return CSR(rpt=rpt, col=col, val=val, shape=(m, n))

    return run


# ---------------------------------------------------------------------------
# Request records.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpgemmRequest:
    """One queued (A, B) product awaiting drain()."""

    uid: int
    A: CSR
    B: CSR
    config: SpgemmConfig


@dataclasses.dataclass
class _Finished:
    """Synchronously-completed dispatch (steps path)."""

    uid: int
    result: SpgemmResult
    auto_entry: Optional[CacheEntry] = None  # AUTO_SHARDS policy entry
    span: Optional[Span] = None   # open request/shard span (ends at finalize)
    t0: Optional[float] = None    # dispatch wall-clock (latency histogram)


@dataclasses.dataclass
class _Pending:
    """Asynchronously-dispatched hot-path call awaiting its one host sync."""

    uid: int
    entry: CacheEntry
    plan: SpgemmPlan    # the plan the run was dispatched against: the
                        # entry may be re-specialized while we're in flight
    A: CSR
    B: CSR
    handles: tuple      # (C, total_nprod, total_nnz, sym_binning, num_binning
                        #  [, ...phase scalars][, ws_i32, ws_val when leased])
    t0: float
    auto_entry: Optional[CacheEntry] = None  # AUTO_SHARDS policy entry
    span: Optional[Span] = None   # open request/shard span (ends at finalize)
    lease: Optional[Lease] = None  # arena workspace checked out at dispatch
    # Host-side phase wall-clocks captured at dispatch (estimate-mode cold
    # calls: estimate/build/compile_dispatch) — merged into the finalized
    # SpgemmResult.timings so benchmarks see the cold-phase breakdown.
    timings: Dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _ShardedPending:
    """A request fanned out into per-shard sub-dispatches awaiting merge.

    Each element of ``shard_recs`` is an ordinary record (_Finished from a
    cold shard, _Pending from a hot one) with its own verify sync; the
    merge finalizer verifies the slice storage buckets (redoing any
    truncated shard), then concatenates the per-shard CSRs."""

    uid: int
    entry: CacheEntry   # the PARENT (sharded) plan's cache entry
    spec: ShardSpec     # the partition the shards were sliced with
    shard_recs: List["_Record"]
    A: CSR              # the canonicalized operands, kept for slice
    B: CSR              # verification and overflowed-shard redo
    config: SpgemmConfig
    t0: float
    auto_entry: Optional[CacheEntry] = None  # AUTO_SHARDS policy entry
    span: Optional[Span] = None   # open request span (ends at finalize)


_Record = Union[_Finished, _Pending, _ShardedPending]


def _record_ready(rec: _Record) -> bool:
    """Whether a record's device work has completed (non-blocking probe).

    Backends whose arrays lack ``is_ready`` report True — the completion-
    order drain then degrades gracefully to dispatch order."""
    if isinstance(rec, _Finished):
        return True
    if isinstance(rec, _ShardedPending):
        return all(_record_ready(r) for r in rec.shard_recs)
    return all(leaf.is_ready() for leaf in jax.tree_util.tree_leaves(rec.handles)
               if hasattr(leaf, "is_ready"))


class SpgemmEngine:
    """Streaming SpGEMM front-end: plan cache + batched async executor.

    Usage::

        engine = SpgemmEngine()
        r = engine.execute(A, B)                 # synchronous, plan-cached

        engine.submit(A1, B1); engine.submit(A2, B2)
        results = engine.drain()    # batched, completion-order finalize

    ``execute`` is what ``repro.core.spgemm`` wraps; ``submit``/``drain``
    is the serving-path API: requests grouped by plan, a bounded window
    of dispatches in flight, pending work finalized as it completes
    (``drain(drain_ordered=True)`` restores dispatch-order finalize).

    ``shards=N`` makes every plan partition-aware: requests fan out into N
    flop-balanced row-block sub-dispatches of A (pow-2-bucketed slice
    signatures, so shard plans hit the cache) whose CSR results a jitted
    merge finalizer concatenates back — one plan, N shards.  ``mesh``
    optionally places shard s on the s-th data-axis device of a
    ``launch/mesh.py`` mesh (replicated B, row-sharded A).

    ``shards="auto"`` replaces the static knob with the adaptive policy
    (``engine/autotune.py``): N is learned per plan from the cold flop
    estimate bounded by device occupancy, and revised from finalize
    telemetry when the stream's flop mean drifts (tiny products collapse
    to N=1).  ``policy`` tunes the :class:`AdaptivePolicy` knobs — it
    also governs the tracked-jitter hash-schedule headroom (grow on
    overflow, trim on sustained eviction-free streaks).
    """

    def __init__(self, config: Optional[SpgemmConfig] = None, *,
                 cache_capacity: int = 64,
                 shards: Union[int, str] = 1, mesh=None,
                 policy: Optional[AdaptivePolicy] = None,
                 telemetry: Union[Telemetry, bool, None] = None,
                 arena: Optional[Arena] = None,
                 governor: Optional[MemoryGovernor] = None,
                 faults: Optional[FaultPlan] = None):
        assert shards == "auto" or shards >= 1, shards
        self.config = config or SpgemmConfig()
        self.shards = shards
        self.mesh = mesh
        self.policy = policy or AdaptivePolicy()
        # Workspace arena + memory governor: by default every engine in
        # the process shares ONE arena (multi-tenant traffic is bounded
        # together); pass an explicit Arena for isolation.  The governor
        # default is unbounded — set ``MemoryGovernor(cap_bytes=...)`` to
        # turn the degradation ladder on.
        self.arena = arena if arena is not None else default_arena()
        self.governor = governor or MemoryGovernor()
        # Structured tracing/metrics (telemetry.py).  Disabled by default:
        # spans/events no-op, but the registry still backs EngineStats /
        # the cache counters, so there is exactly ONE set of numbers.
        self.telemetry = resolve_telemetry(telemetry)
        # Deterministic fault injection (core/faults.py), threaded the
        # same way: the disabled default costs one attribute read per
        # site.  Sites: lease_denial (workspace acquisition), verify_
        # overflow (finalize), executor_raise + slow_dispatch (dispatch).
        self.faults = resolve_faults(faults)
        self.cache = PlanCache(cache_capacity, telemetry=self.telemetry,
                               arena=self.arena)
        self.stats = EngineStats(registry=self.telemetry.registry)
        # Engine-level estimator calibration (plan_mode="estimate"): the
        # tail-quantile headroom is learned ACROSS plans from observed
        # confirm/retrace telemetry — misses are a property of the traffic
        # distribution, not of one signature.
        self.est_state = autotune.EstimatorState(self.policy)
        reg = self.telemetry.registry
        self._hist_request = reg.histogram("opsparse_request_latency_seconds")
        self._hist_cold = reg.histogram("opsparse_cold_steps_seconds")
        self._hist_finalize = reg.histogram("opsparse_finalize_seconds")
        # Arena gauges/counters: snapshot-set from the (possibly shared)
        # arena's own accounting on every lease transition, so multiple
        # engines publishing into their own registries agree.
        self._arena_gauges = {
            "opsparse_arena_bytes_in_use": reg.gauge(
                "opsparse_arena_bytes_in_use"),
            "opsparse_arena_bytes_reserved": reg.gauge(
                "opsparse_arena_bytes_reserved"),
            "opsparse_arena_peak_bytes": reg.gauge(
                "opsparse_arena_peak_bytes"),
            "opsparse_arena_lease_hits_total": reg.gauge(
                "opsparse_arena_lease_hits_total"),
            "opsparse_arena_lease_misses_total": reg.gauge(
                "opsparse_arena_lease_misses_total"),
            "opsparse_arena_pressure_events_total": reg.gauge(
                "opsparse_arena_pressure_events_total"),
        }
        self._queue: List[SpgemmRequest] = []
        self._uids = itertools.count()
        # Per-device replicated-B memo for the mesh path.  Streams reuse
        # the same B request after request (the repeated-adjacency
        # pattern), so B ships to each non-home device ONCE, not once per
        # dispatch.  A new B clears the WHOLE memo (identity check on the
        # source array) so stale replicas don't pin device memory.
        self._b_src = None
        self._b_placed: Dict = {}

    # -- public API ---------------------------------------------------------
    def _effective_config(self, config: Optional[SpgemmConfig]) -> SpgemmConfig:
        """Resolve the per-call config.  The engine-level ``shards`` knob
        (an int, or ``"auto"`` = AUTO_SHARDS adaptive selection) only
        folds into the engine's own default config — an explicitly passed
        config is taken verbatim, so ``SpgemmConfig(shards=1)`` opts a
        single call out of engine-level sharding."""
        if config is not None:
            return config
        config = self.config
        if self.shards != 1 and config.shards == 1:
            shards = AUTO_SHARDS if self.shards == "auto" else self.shards
            config = dataclasses.replace(config, shards=shards)
        return config

    def execute(self, A: CSR, B: CSR,
                config: Optional[SpgemmConfig] = None) -> SpgemmResult:
        """Plan-then-execute one product (the ``spgemm()`` backend)."""
        rec = self._dispatch(next(self._uids), A, B,
                             self._effective_config(config))
        return self._finalize(rec)

    def prewarm(self, A: CSR, B: CSR,
                config: Optional[SpgemmConfig] = None, *,
                prod_bucket: Optional[int] = None,
                nnz_bucket: Optional[int] = None) -> SpgemmPlan:
        """Ahead-of-time plan specialization (no execution).

        Seeds the plan for (A, B)'s signatures with caller-provided
        capacity buckets — Liu & Vinter-style ahead-of-time allocation
        for workloads whose product sizes are known (or bounded) up
        front, e.g. a BFS whose frontiers grow hop over hop.  The first
        real request then goes straight to the jitted hot path instead
        of paying a cold discovery call plus progressive regrows.

        With NO buckets supplied the sampling estimator sizes the plan
        instead (``core/analysis.estimate_result``): capacities, and for
        hash configs the full launch schedule — so an estimator prewarm
        fully specializes even hash plans, which explicit buckets alone
        cannot (they lack the schedule).

        Capacity buckets are per-(sub-)problem state, which a sharded
        parent plan doesn't hold — its partition needs data the caller
        can't supply here.  On a sharded engine, pass an explicit
        unsharded config (or prewarm via :meth:`PlanCache.load`).
        """
        config = self._effective_config(config)
        if config.shards != 1:       # not assert: must survive python -O
            raise ValueError(
                "prewarm seeds capacity buckets, which sharded (or "
                "AUTO_SHARDS) plans don't use; pass SpgemmConfig(shards=1) "
                "or PlanCache.load() a dump")
        a_sig, b_sig = MatrixSig.of(A), MatrixSig.of(B)
        entry = self.cache.get((a_sig, b_sig, config))
        if entry is None:
            entry = self.cache.insert(make_plan(a_sig, b_sig, config))
        if prod_bucket is None and nnz_bucket is None:
            if not entry.plan.is_specialized:
                uid = next(self._uids)
                with self.telemetry.span("estimate", uid=uid,
                                         prewarm=True):
                    self._estimate_specialize(
                        entry, A.with_capacity(a_sig.cap_bucket),
                        B.with_capacity(b_sig.cap_bucket), uid)
            return entry.plan
        if prod_bucket is None or nnz_bucket is None:
            raise ValueError(
                "pass both prod_bucket and nnz_bucket, or neither "
                "(estimator-sized prewarm)")
        self.cache.specialize(entry, entry.plan.with_capacities(
            max(entry.plan.prod_bucket or 0,
                next_bucket(max(prod_bucket, 1))),
            max(entry.plan.nnz_bucket or 0,
                next_bucket(max(nnz_bucket, 1)))))
        return entry.plan

    def _estimate_specialize(self, entry: CacheEntry, A: CSR, B: CSR,
                             uid: int) -> Dict[str, float]:
        """Specialize a cold plan from the sampled estimator
        (``plan_mode="estimate"`` — the Ocean-style cold path).

        The exact cold path runs the FULL symbolic phase (and, two-pass,
        a second probe pass) just to size buckets.  Here the per-row
        n_prod fetch — the same host sync the flop partitioner pays —
        yields the EXACT symbolic-side schedule, and a small measured row
        sample bands the compression ratio to predict the nnz bucket and
        the numeric-side rung counts.  The plan is specialized in one
        step (capacities + hash launch schedule) with its policy marked
        ``estimated=True``; the finalize verify confirms it on the first
        admitted call, and an under-estimate pays one overflow-grow
        retrace (bitwise-equal result via the steps oracle) while the
        engine-level :class:`~repro.engine.autotune.EstimatorState`
        grows the tail headroom for the next cold plan.

        Returns the host wall-clock as a timings fragment
        (``{"estimate": seconds}``) for the cold-phase breakdown.
        """
        plan = entry.plan
        config = plan.config
        t0 = time.perf_counter()
        est = estimate_result(
            A, B,
            sym_upper=plan.sym_ladder.upper,
            num_upper=plan.num_ladder.upper,
            n_sample=self.policy.est_sample_rows,
            quantile=self.policy.est_quantile,
            headroom=self.est_state.headroom)
        self.stats.estimates += 1
        self.telemetry.event(
            "estimate", uid=uid, sampled_rows=est.sampled_rows,
            r_lo=est.r_lo, r_hi=est.r_hi, total_nprod=est.total_nprod,
            total_nnz_high=est.total_nnz_high,
            est_headroom=self.est_state.headroom)
        prod_cap = max(plan.prod_bucket or 0,
                       next_bucket(max(int(est.total_nprod
                                           * _CAPACITY_HEADROOM), 1)))
        nnz_cap = max(plan.nnz_bucket or 0,
                      next_bucket(max(int(est.total_nnz_high
                                          * _CAPACITY_HEADROOM), 1)))
        state = plan.policy or PolicyState(
            headroom=self.policy.headroom_init)
        specialized = plan.with_capacities(prod_cap, nnz_cap)
        if config.method == "hash":
            # Same bucket math as host_schedule/trim_schedule (the ONE
            # shared copy in spgemm_hash), fed estimated counts: exact
            # rows per sym rung, band-high rows per num rung, and the
            # band-high fallback products shared by both phases.
            m_cap = next_bucket(plan.a_sig.nrows,
                                minimum=spgemm_hash._ROW_BUCKET_MIN)
            packs = (plan.sym_ladder.rows_per_block
                     if config.row_packing else None)
            sym_buckets = tuple(
                spgemm_hash.schedule_bucket(
                    c, m_cap=m_cap, headroom=state.headroom,
                    pack=(packs[b] if packs is not None and b < len(packs)
                          else 1))
                for b, c in enumerate(est.sym_counts))
            num_buckets = tuple(
                spgemm_hash.schedule_bucket(c, m_cap=m_cap,
                                            headroom=state.headroom)
                for c in est.num_counts)
            fall = max(est.sym_fall_prod, est.num_fall_prod)
            fall_bucket = (spgemm_hash.fallback_capacity_bucket(
                fall, headroom=state.headroom) if fall else 0)
            sched = HashSchedule(sym_buckets, num_buckets, fall_bucket)
            if plan.hash_schedule is not None:
                sched = sched.union(plan.hash_schedule)
            specialized = specialized.with_hash_schedule(sched)
        self.cache.specialize(
            entry, specialized.with_policy(state.with_estimated(True)))
        return {"estimate": time.perf_counter() - t0}

    def submit(self, A: CSR, B: CSR,
               config: Optional[SpgemmConfig] = None) -> int:
        """Queue a request; returns its uid (resolved by ``drain``)."""
        assert A.ncols == B.nrows, (A.shape, B.shape)
        uid = next(self._uids)
        self._queue.append(
            SpgemmRequest(uid, A, B, self._effective_config(config)))
        return uid

    def drain(self, *, drain_ordered: bool = False,
              window: int = 4) -> Dict[int, SpgemmResult]:
        """Run all queued requests; returns {uid: result}.

        Requests are grouped by plan signature (group members share one
        executable) and pipelined: up to ``window`` dispatches stay in
        flight, and pending records are finalized in COMPLETION order —
        whichever device work finishes first gets its verify sync first,
        so a slow mixed-size request no longer head-of-line-blocks the
        small ones dispatched after it.  ``drain_ordered=True`` restores
        the PR-1 dispatch-order double-buffered finalize (compat flag; the
        return type is identical either way).
        """
        queue, self._queue = self._queue, []
        self.stats.drains += 1
        groups: "OrderedDict[tuple, List[SpgemmRequest]]" = OrderedDict()
        for req in queue:
            key = (MatrixSig.of(req.A), MatrixSig.of(req.B), req.config)
            groups.setdefault(key, []).append(req)
        ordered = itertools.chain.from_iterable(groups.values())

        # The drain span parents every request span opened inside it (via
        # the tracer's thread-local stack), so the Perfetto view groups a
        # whole batch — including finalizes the completion-order loop
        # reordered — under one interval.
        results: Dict[int, SpgemmResult] = {}
        with self.telemetry.span("drain", n_requests=len(queue),
                                 ordered=drain_ordered):
            if drain_ordered:
                inflight: Optional[_Record] = None
                for req in ordered:
                    try:
                        rec = self._dispatch(req.uid, req.A, req.B,
                                             req.config)
                    except ArenaPressureError:
                        # Backpressure: finalize the in-flight record
                        # (returning its lease) and retry once; with
                        # nothing in flight the cap is simply too small.
                        if inflight is None:
                            raise
                        results[inflight.uid] = self._finalize(inflight)
                        inflight = None
                        rec = self._dispatch(req.uid, req.A, req.B,
                                             req.config)
                    if inflight is not None:
                        if not isinstance(inflight, _Finished):
                            self.stats.overlapped += 1  # planned k+1, k ran
                        results[inflight.uid] = self._finalize(inflight)
                    inflight = rec
                if inflight is not None:
                    results[inflight.uid] = self._finalize(inflight)
                return results

            pending: List[_Record] = []
            window = max(1, int(window))
            for req in ordered:
                # Reap down BEFORE dispatching: appending first would hold
                # window+1 concurrent dispatches (off-by-one — the window
                # is a device-memory bound, so it must hold at dispatch).
                while len(pending) >= window:
                    self._reap_one(pending, results)
                while True:
                    try:
                        rec = self._dispatch(req.uid, req.A, req.B,
                                             req.config)
                        break
                    except ArenaPressureError:
                        # Backpressure: finalize one in-flight record
                        # (returning its lease) and retry; with nothing
                        # in flight the cap is simply too small.
                        if not pending:
                            raise
                        self._reap_one(pending, results)
                if any(not isinstance(r, _Finished) for r in pending):
                    self.stats.overlapped += 1   # planned k+1 while k ran
                pending.append(rec)
                self.stats.peak_inflight = max(self.stats.peak_inflight,
                                               len(pending))
            while pending:
                self._reap_one(pending, results)
        return results

    def _reap_one(self, pending: List[_Record],
                  results: Dict[int, SpgemmResult]) -> None:
        """Finalize ONE pending record, preferring completed device work;
        with nothing complete yet, fall back to the oldest dispatch."""
        for i, rec in enumerate(pending):
            if _record_ready(rec):
                if i:
                    self.stats.reordered += 1
                pending.pop(i)
                results[rec.uid] = self._finalize(rec)
                return
        rec = pending.pop(0)
        results[rec.uid] = self._finalize(rec)

    def report(self) -> str:
        return stats_mod.render(self)

    # -- internals ----------------------------------------------------------
    def _update_arena_gauges(self) -> None:
        """Snapshot the (possibly shared) arena's accounting into this
        engine's registry gauges.  Called on every lease transition and
        by ``prometheus_text`` just before rendering, so scrapes see
        fresh numbers even for engines idle since their last lease."""
        a = self.arena
        g = self._arena_gauges
        g["opsparse_arena_bytes_in_use"].set(a.bytes_in_use)
        g["opsparse_arena_bytes_reserved"].set(a.bytes_reserved)
        g["opsparse_arena_peak_bytes"].set(a.peak_bytes)
        g["opsparse_arena_lease_hits_total"].set(a.lease_hits)
        g["opsparse_arena_lease_misses_total"].set(a.lease_misses)
        g["opsparse_arena_pressure_events_total"].set(a.pressure_events)

    # -- fault-injection site shims (core/faults.py) ------------------------
    def _note_fault(self, site: str, uid: int) -> None:
        self.stats.faults_injected += 1
        self.telemetry.event("fault_injected", uid=uid, site=site)

    def _consult_dispatch_faults(self, uid: int) -> None:
        """``executor_raise`` + ``slow_dispatch`` sites, consulted once
        per user-visible request (shard sub-dispatches excluded — the
        consult rides the same guard as ``stats.requests``)."""
        faults = self.faults
        if not faults.enabled:
            return
        spec = faults.fire("executor_raise", uid=uid)
        if spec is not None:
            self._note_fault("executor_raise", uid)
            raise InjectedFault(
                spec.message or f"injected executor fault (uid={uid})",
                site="executor_raise", transient=spec.transient)
        spec = faults.fire("slow_dispatch", uid=uid)
        if spec is not None and spec.delay_s > 0:
            self._note_fault("slow_dispatch", uid)
            time.sleep(spec.delay_s)

    def _try_lease(self, spec, cap, device, uid: int) -> Optional[Lease]:
        """Arena acquisition with the ``lease_denial`` site in front: an
        injected denial is indistinguishable from the cap binding, so the
        governor ladder (and the drain/service backpressure above it)
        runs for real without real memory pressure.  Each acquisition
        attempt — including post-reclaim and post-trim retries — is one
        site visit, so a spec's ``at`` indices control ladder depth."""
        if self.faults.enabled \
                and self.faults.fire("lease_denial", uid=uid) is not None:
            self._note_fault("lease_denial", uid)
            return None
        return self.arena.try_acquire(spec, cap, device)

    def _forced_overflow(self, uid: int) -> bool:
        """``verify_overflow`` site: one visit per hot-path finalize."""
        if not self.faults.enabled:
            return False
        if self.faults.fire("verify_overflow", uid=uid) is None:
            return False
        self._note_fault("verify_overflow", uid)
        return True

    def _lease_workspace(self, entry: CacheEntry, uid: int,
                         device=None) -> Tuple[Optional[Lease], bool]:
        """Check the plan's workspace out of the arena, walking the
        governor's degradation ladder under pressure.

        Returns ``(lease, spill)``: ``lease`` is ``None`` for plans with
        nothing leasable (``workspace_spec() is None``) and under a spill;
        ``spill=True`` routes THIS call through the unleased two-pass
        steps path.  Raises :class:`ArenaPressureError` when the ladder is
        exhausted (``drain`` answers it with backpressure: finalize one
        in-flight record — returning its lease — then retry)."""
        spec = entry.plan.workspace_spec()
        if spec is None:
            return None, False
        cap = self.governor.cap_bytes
        lease = self._try_lease(spec, cap, device, uid)
        if lease is None:
            # rung 0: the cap is binding — count pressure, drop idle
            # pooled buffers, retry.
            self.arena.note_pressure()
            self.stats.arena_pressure += 1
            self.telemetry.event("arena_pressure", uid=uid,
                                 want_bytes=spec.nbytes, cap_bytes=cap,
                                 reserved=self.arena.bytes_reserved)
            self.arena.reclaim()
            lease = self._try_lease(spec, cap, device, uid)
        if lease is None and self.governor.trim_under_pressure:
            # rung 1: forced headroom trim — re-derive the hash schedule
            # at the policy floor from the streak's observed maxima,
            # shrinking this plan's lease spec (drops the executable for
            # one rebuild; the trace is against the smaller shapes).
            plan = entry.plan
            state = plan.policy
            if (plan.config.method == "hash" and plan.hash_schedule is not None
                    and state is not None and state.sym_max is not None):
                forced = dataclasses.replace(
                    state, headroom=self.policy.headroom_min)
                trimmed = autotune.trim_schedule(
                    forced, plan.hash_schedule, m=plan.a_sig.nrows,
                    sym_ladder=plan.sym_ladder,
                    packed=plan.config.row_packing,
                    fused=plan.config.fuse_numeric, policy=self.policy)
                if trimmed is not None:
                    self.stats.arena_trims += 1
                    entry.stats.schedule_trims += 1
                    self.telemetry.event("arena_trim", uid=uid)
                    self.cache.specialize(
                        entry,
                        plan.with_hash_schedule(HashSchedule(*trimmed))
                        .with_policy(forced.after_trim(self.policy)))
                    spec = entry.plan.workspace_spec()
                    if spec is None:
                        return None, False
                    lease = self._try_lease(spec, cap, device, uid)
        if lease is None and self.governor.spill_fused \
                and entry.plan.config.method == "hash" \
                and entry.plan.config.fuse_numeric:
            # rung 2: spill the fused plan to the two-pass steps oracle
            # for this call — no lease, no arena growth, result parity.
            # Hash-fused only: an ESC "spill" would still allocate the
            # same workspace per call, just outside arena accounting.
            self.stats.arena_spills += 1
            self.telemetry.event("arena_spill", uid=uid)
            return None, True
        if lease is None:
            # rung 3: refuse — the caller must return leases first.
            raise ArenaPressureError(
                f"workspace lease of {spec.nbytes} bytes exceeds the "
                f"governor cap ({cap} bytes; "
                f"{self.arena.bytes_reserved} reserved)")
        self._update_arena_gauges()
        return lease, False

    def _release_ws(self, rec: "_Pending") -> None:
        """Finalize-side half of the donation loop: rebind the lease to
        the workspace arrays the executable RETURNED (the donated inputs
        were consumed; XLA aliased the outputs onto their blocks) and
        return them to the arena's free lists."""
        if rec.lease is not None:
            lease, rec.lease = rec.lease, None
            self.arena.release(lease, rebind=rec.handles[-2:])
            if lease in rec.entry.leases:
                rec.entry.leases.remove(lease)
            self._update_arena_gauges()

    def _dispatch(self, uid: int, A: CSR, B: CSR, config: SpgemmConfig, *,
                  _sub: bool = False,
                  _parent: Optional[Span] = None) -> _Record:
        assert A.ncols == B.nrows, (A.shape, B.shape)
        if config.shards == AUTO_SHARDS:
            auto_entry, config = self._resolve_auto_shards(A, B, config)
            rec = self._dispatch(uid, A, B, config, _sub=_sub,
                                 _parent=_parent)
            rec.auto_entry = auto_entry   # finalize feeds telemetry back
            return rec
        if config.shards > 1:
            if A.nrows >= 2:
                return self._dispatch_sharded(uid, A, B, config)
            # Nothing to partition: run (and key the plan) unsharded so
            # the request still reaches the jitted steady state.
            config = dataclasses.replace(config, shards=1)
        if not _sub:       # shard sub-dispatches aren't user requests
            self.stats.requests += 1
            self._consult_dispatch_faults(uid)
        t0 = time.perf_counter()
        tel = self.telemetry
        # The request (or, under the sharded fan-out, per-shard) span
        # stays OPEN across the async dispatch->finalize split: it rides
        # the record and _finalize closes it after the verify sync.
        span = tel.start_span("shard" if _sub else "request",
                              parent=_parent, uid=uid, method=config.method)
        a_sig, b_sig = MatrixSig.of(A), MatrixSig.of(B)
        with tel.span("plan_lookup", parent=span, uid=uid) as lookup:
            entry = self.cache.get((a_sig, b_sig, config))
            lookup.set(hit=entry is not None)
            if entry is None:
                entry = self.cache.insert(make_plan(a_sig, b_sig, config))
        entry.stats.calls += 1

        # Canonicalize operand storage to the signature buckets so every
        # request in the bucket presents identical static shapes.
        A = A.with_capacity(a_sig.cap_bucket)
        B = B.with_capacity(b_sig.cap_bucket)

        plan = entry.plan
        est_timings: Optional[Dict[str, float]] = None
        if (config.plan_mode == "estimate" and not plan.is_specialized
                and config.method in ("esc", "hash") and not config.timing):
            # Estimation-based cold path: specialize straight from the
            # sampled estimator and fall through to the jitted hot path —
            # the full symbolic sizing pass never runs.  The finalize
            # verify (+ overflow-grow retrace) is the correctness net.
            with tel.span("estimate", parent=span, uid=uid):
                est_timings = self._estimate_specialize(entry, A, B, uid)
            plan = entry.plan
        hot_eligible = (plan.is_specialized
                        and config.method in ("esc", "hash")
                        and not config.timing)
        if not hot_eligible:
            state = plan.policy or PolicyState(
                headroom=self.policy.headroom_init)
            # StepTimer carries the tracer, so the six paper steps (setup,
            # binnings, symbolic, alloc, numeric) emit kernel-phase spans
            # nested under cold_steps — attribution on exactly the path
            # that already host-syncs per step.  Truly-cold calls keep the
            # timer on even untraced so benchmarks get the cold-phase
            # breakdown (the steps path host-syncs per step anyway).
            with tel.span("cold_steps", parent=span, uid=uid,
                          specialized=plan.is_specialized) as cold:
                result, prod_cap, nnz_cap, hash_sched = _execute_steps(
                    A, B, plan,
                    StepTimer(config.timing or not plan.is_specialized,
                              tracer=tel, uid=uid),
                    headroom=state.headroom)
            if tel.enabled:
                self._hist_cold.observe(cold.dur)
            if not plan.is_specialized:
                # Progressive allocation: learn the buckets (and, for the
                # hash method, the launch schedule the run just used) for
                # steady state.
                specialized = plan.with_capacities(prod_cap, nnz_cap)
                if hash_sched is not None:
                    specialized = specialized.with_hash_schedule(hash_sched)
                    specialized = specialized.with_policy(state)
                self.cache.specialize(entry, specialized)
            entry.stats.steps_calls += 1
            entry.stats.time_s += time.perf_counter() - t0
            return _Finished(uid, result, span=span, t0=t0)

        # Check the workspace out of the arena BEFORE touching the
        # executable: a forced pressure trim re-specializes the entry
        # (shrinking the traced shapes), so the build below must see the
        # post-ladder plan.
        devs = A.val.devices()
        lease, spill = self._lease_workspace(
            entry, uid, device=next(iter(devs)) if len(devs) == 1 else None)
        if spill:
            # Fused->two-pass spill: this call runs the unleased steps
            # oracle (bitwise-identical result); the plan and its fused
            # executable stay cached for when pressure clears.
            state = entry.plan.policy or PolicyState(
                headroom=self.policy.headroom_init)
            with tel.span("arena_spill_steps", parent=span, uid=uid):
                result, _, _, _ = _execute_steps(
                    A, B, entry.plan,
                    StepTimer(config.timing, tracer=tel, uid=uid),
                    headroom=state.headroom)
            entry.stats.steps_calls += 1
            entry.stats.time_s += time.perf_counter() - t0
            return _Finished(uid, result, span=span, t0=t0)
        plan = entry.plan
        if lease is not None:
            entry.leases.append(lease)   # eviction forfeits outstanding ones
        if entry.executable is None:
            with tel.span("build_executable", parent=span, uid=uid):
                t_build = time.perf_counter()
                if config.method != "hash":
                    builder = _build_hot_executable
                elif config.fuse_numeric:
                    builder = _build_fused_hash_executable
                else:
                    builder = _build_hash_executable
                entry.executable = builder(plan)
                if est_timings is not None:
                    est_timings["build"] = time.perf_counter() - t_build
        with tel.span("dispatch", parent=span, uid=uid):
            t_disp = time.perf_counter()
            if lease is None:
                handles = entry.executable(A, B)   # async dispatch, no sync
            else:
                handles = entry.executable(A, B, lease.i32, lease.val)
            if est_timings is not None:
                # First call through a fresh executable: the jit dispatch
                # blocks on trace+compile, so this IS the compile cost.
                est_timings["compile_dispatch"] = time.perf_counter() - t_disp
        entry.stats.hot_calls += 1
        return _Pending(uid, entry, plan, A, B, handles, t0, span=span,
                        lease=lease, timings=est_timings or {})

    def _dispatch_sharded(self, uid: int, A: CSR, B: CSR,
                          config: SpgemmConfig) -> _Record:
        """Fan one request out into per-shard row-block sub-dispatches.

        The parent plan owns the learned :class:`ShardSpec`; each shard's
        A slice is padded to the spec's pow-2 row/storage buckets and
        dispatched through the ordinary (unsharded) plan machinery, so
        shards reuse the existing ESC/hash executables — and shards whose
        buckets coincide share ONE sub-plan.  Per-shard slice overflow
        grows only that shard's bucket (and hence only that shard's plan).
        """
        self.stats.requests += 1
        self.stats.sharded_requests += 1
        self._consult_dispatch_faults(uid)
        t0 = time.perf_counter()
        tel = self.telemetry
        span = tel.start_span("request", uid=uid, method=config.method,
                              shards=config.shards)
        a_sig, b_sig = MatrixSig.of(A), MatrixSig.of(B)
        with tel.span("plan_lookup", parent=span, uid=uid) as lookup:
            entry = self.cache.get((a_sig, b_sig, config))
            lookup.set(hit=entry is not None)
            if entry is None:
                entry = self.cache.insert(make_plan(a_sig, b_sig, config))
        entry.stats.calls += 1

        spec = entry.plan.shard_spec
        if spec is None:
            # Cold call: ONE host read of the flop estimate balances the
            # row blocks; the partition is then pinned so steady-state
            # shard signatures never move.  Steady-state dispatch stays
            # sync-free — whether this request's slices FIT the learned
            # storage buckets is checked in the finalize sync (an
            # overflowed slice would be silently truncated, which the
            # sub-plans can't detect themselves).
            with tel.span("partition", parent=span, uid=uid):
                flops = row_flops(A, B)        # host int64 (its one sync)
                rpt = jax.device_get(A.rpt)
                spec = plan_shards(rpt, flops, config.shards, telemetry=tel)
                self.cache.specialize(entry,
                                      entry.plan.with_shard_spec(spec))

        if entry.executable is None:
            with tel.span("build_executable", parent=span, uid=uid):
                entry.executable = _build_merge_executable(
                    spec, m=A.nrows, n=B.ncols)

        devices = (shard_devices(self.mesh, spec.n_shards)
                   if self.mesh is not None else None)
        sub_cfg = dataclasses.replace(config, shards=1)
        shard_recs: List[_Record] = []
        for s in range(spec.n_shards):
            A_s = A.row_slice(spec.bounds[s], spec.bounds[s + 1],
                              nrows=spec.row_buckets[s],
                              capacity=spec.cap_buckets[s])
            B_s = B
            if devices is not None:
                dev = devices[s]
                A_s = jax.device_put(A_s, dev)          # row-sharded A
                if self._b_src is not B.val:            # new B: drop replicas
                    self._b_src = B.val
                    self._b_placed = {}
                if dev not in self._b_placed:
                    self._b_placed[dev] = (B if dev in B.val.devices()
                                           else jax.device_put(B, dev))
                B_s = self._b_placed[dev]
            try:
                rec = self._dispatch(uid, A_s, B_s, sub_cfg, _sub=True,
                                     _parent=span)
            except ArenaPressureError:
                # Unwind the fan-out: finalize the shards already in
                # flight so their leases return, then re-raise — drain's
                # backpressure handler redispatches the whole request.
                for r in shard_recs:
                    self._finalize(r)
                if tel.enabled and isinstance(span, Span):
                    tel.end_span(span)
                raise
            if rec.span is not None:
                rec.span.set(shard=s)
            shard_recs.append(rec)
        return _ShardedPending(uid, entry, spec, shard_recs, A, B,
                               config, t0, span=span)

    # -- adaptive shard count (AUTO_SHARDS) ---------------------------------
    def _device_count(self) -> int:
        """Per-shard occupancy bound: the devices shards could land on."""
        if self.mesh is not None:
            return len(data_axis_devices(self.mesh))
        return jax.local_device_count()

    def _resolve_auto_shards(self, A: CSR, B: CSR, config: SpgemmConfig):
        """Turn an AUTO_SHARDS config into a concrete one via the policy.

        The decision lives on the AUTO plan entry (keyed by the unresolved
        config), so it is learned once per signature — ONE host read of
        the flop estimate on the cold request, like the shard partitioner
        — then pinned; finalize-side telemetry (:meth:`_note_auto`) can
        revise it when the stream's flop mean drifts out of the
        hysteresis band (shrinking to 1 for tiny products where the merge
        finalizer dominates).
        """
        self.stats.auto_requests += 1
        a_sig, b_sig = MatrixSig.of(A), MatrixSig.of(B)
        entry = self.cache.get((a_sig, b_sig, config))
        if entry is None:
            entry = self.cache.insert(make_plan(a_sig, b_sig, config))
        state = entry.plan.policy
        if state is None or state.shard_decision is None:
            flops = row_flops(A, B)          # host int64 (the one sync)
            total = int(flops.sum())
            n = autotune.choose_shards(total, A.nrows, self._device_count(),
                                       self.policy,
                                       telemetry=self.telemetry)
            state = ((state or PolicyState(headroom=self.policy.headroom_init))
                     .with_shard_decision(n, total))
            self.cache.update_policy(entry, state)
        n = state.shard_decision
        return entry, dataclasses.replace(config, shards=max(n, 1))

    def _note_auto(self, entry: CacheEntry, result: SpgemmResult) -> None:
        """Feed one finalized request's flop estimate back to its AUTO
        plan's policy, revising the shard decision on sustained drift."""
        state = entry.plan.policy
        if state is None:
            return
        state = state.note_flops(2 * result.total_nprod)
        state, revised = autotune.revise_shards(
            state, entry.plan.a_sig.nrows, self._device_count(), self.policy,
            telemetry=self.telemetry)
        if revised:
            self.stats.policy_revisions += 1
        self.cache.update_policy(entry, state)

    def _finalize(self, rec: _Record) -> SpgemmResult:
        tel = self.telemetry
        with tel.span("finalize", parent=rec.span, uid=rec.uid) as fin:
            result = self._finalize_record(rec)
        if rec.auto_entry is not None:
            self._note_auto(rec.auto_entry, result)
        if tel.enabled:
            self._hist_finalize.observe(fin.dur)
            span = rec.span
            if isinstance(span, Span):
                # Close the open request/shard span the dispatch left on
                # the record (idempotent under redo paths).
                tel.end_span(span)
                if span.name == "request" and rec.t0 is not None:
                    self._hist_request.observe(span.t1 - rec.t0)
        return result

    def _finalize_record(self, rec: _Record) -> SpgemmResult:
        if isinstance(rec, _ShardedPending):
            return self._finalize_sharded(rec)
        if isinstance(rec, _Finished):
            return rec.result

        # Verify against the DISPATCH-TIME plan: a concurrent overflow may
        # have re-specialized the entry with larger buckets than this run
        # actually executed with, and passing its check would return a
        # silently truncated C.
        plan = rec.plan
        handles = (rec.handles[:-2] if rec.lease is not None
                   else rec.handles)   # the lease rides as the last pair
        if plan.config.method == "hash" and plan.config.fuse_numeric:
            C, tnp, tnz, sym_binning, num_binning, sym_fall = handles
            # The ONE host sync: totals + sym bin sizes + fallback product
            # (num_binning is telemetry only — no numeric pass to verify).
            with self.telemetry.span("verify_sync", uid=rec.uid):
                fetched = jax.device_get(
                    (tnp, tnz, sym_binning.bin_size, sym_fall))
            self._release_ws(rec)    # sync done: the workspace is idle
            total_nprod, total_nnz = int(fetched[0]), int(fetched[1])
            schedule_ok = plan.hash_schedule.admits_fused(
                fetched[2], int(fetched[3]))
            if not schedule_ok:
                self.stats.bin_overflows += 1
                rec.entry.stats.bin_overflows += 1
            if not schedule_ok or total_nnz > plan.nnz_bucket \
                    or self._forced_overflow(rec.uid):
                return self._grow_and_redo(rec, total_nprod, total_nnz,
                                           schedule_overflow=not schedule_ok)
            self._note_hash_admit(rec, fetched[2], fetched[3])
        elif plan.config.method == "hash":
            (C, tnp, tnz, sym_binning, num_binning,
             sym_fall, num_fall) = handles
            # The ONE host sync: totals + bin sizes + fallback products.
            with self.telemetry.span("verify_sync", uid=rec.uid):
                fetched = jax.device_get(
                    (tnp, tnz, sym_binning.bin_size, num_binning.bin_size,
                     sym_fall, num_fall))
            self._release_ws(rec)    # sync done: the workspace is idle
            total_nprod, total_nnz = int(fetched[0]), int(fetched[1])
            schedule_ok = plan.hash_schedule.admits(
                fetched[2], fetched[3], int(fetched[4]), int(fetched[5]))
            if not schedule_ok:
                self.stats.bin_overflows += 1
                rec.entry.stats.bin_overflows += 1
            if not schedule_ok or total_nnz > plan.nnz_bucket \
                    or self._forced_overflow(rec.uid):
                return self._grow_and_redo(rec, total_nprod, total_nnz,
                                           schedule_overflow=not schedule_ok)
            self._note_hash_admit(rec, fetched[2], fetched[4],
                                  num_sizes=fetched[3], num_fall=fetched[5])
        else:
            C, tnp, tnz, sym_binning, num_binning = handles
            with self.telemetry.span("verify_sync", uid=rec.uid):
                total_nprod, total_nnz = (            # the ONE host sync
                    int(x) for x in jax.device_get((tnp, tnz)))
            self._release_ws(rec)    # sync done: the workspace is idle
            if (total_nprod > plan.prod_bucket
                    or total_nnz > plan.nnz_bucket
                    or self._forced_overflow(rec.uid)):
                return self._grow_and_redo(rec, total_nprod, total_nnz)
            # ESC plans carry no hash schedule, so the estimate
            # confirmation doesn't ride _note_hash_admit — clear the
            # provenance flag here.
            state = rec.entry.plan.policy
            if state is not None and state.estimated:
                self._note_estimate_confirmed(rec.uid)
                self.cache.update_policy(rec.entry,
                                         state.with_estimated(False))

        rec.entry.stats.time_s += time.perf_counter() - rec.t0
        return SpgemmResult(
            C=C, total_nprod=total_nprod, total_nnz=total_nnz,
            sym_binning=sym_binning, num_binning=num_binning,
            timings=dict(rec.timings))

    def _finalize_sharded(self, rec: _ShardedPending) -> SpgemmResult:
        """Merge finalizer: one verify sync per shard (each sub-record's
        ordinary finalize, overflow redo and all), then the jitted
        device-side concatenation of the per-shard CSRs.

        The slice-storage check happens HERE, not at dispatch: a slice
        whose nnz outgrew its learned bucket was silently truncated (the
        sub-plan can't tell — the truncated slice is self-consistent), so
        the boundary gather below is part of the request's verify sync.
        Keeping it out of dispatch keeps sharded dispatch sync-free, so
        drain()'s in-flight window genuinely overlaps sharded requests.
        An overflow grows only the offending shard's bucket and redoes
        only that shard."""
        t_fin = time.perf_counter()
        tel = self.telemetry
        spec = rec.spec
        with tel.span("verify_slices", uid=rec.uid):
            slice_nnz = jax.device_get(
                rec.A.rpt[jnp.asarray(spec.bounds, dtype=jnp.int32)])
        sizes = [int(slice_nnz[s + 1]) - int(slice_nnz[s])
                 for s in range(spec.n_shards)]
        overflowed = [s for s in range(spec.n_shards)
                      if sizes[s] > spec.cap_buckets[s]]
        if overflowed:
            tel.event("shard_grow", uid=rec.uid, shards=tuple(overflowed))
            grown = spec
            for s in overflowed:
                grown = grown.with_cap_bucket(s, 2 * sizes[s])  # headroom
                self.stats.shard_grows += 1
            rec.entry.stats.capacity_grows += len(overflowed)
            current = rec.entry.plan.shard_spec
            if current is not None:     # keep any concurrent growth
                grown = grown.union(current)
            self.cache.specialize(
                rec.entry, rec.entry.plan.with_shard_spec(grown))
            sub_cfg = dataclasses.replace(rec.config, shards=1)
            for s in overflowed:        # redo ONLY the truncated shards
                A_s = rec.A.row_slice(spec.bounds[s], spec.bounds[s + 1],
                                      nrows=grown.row_buckets[s],
                                      capacity=grown.cap_buckets[s])
                rec.shard_recs[s] = self._dispatch(
                    rec.uid, A_s, rec.B, sub_cfg, _sub=True,
                    _parent=rec.span)
        shard_results = [self._finalize(r) for r in rec.shard_recs]
        merge = rec.entry.executable
        if merge is None:     # entry re-specialized while we were in flight
            merge = _build_merge_executable(
                rec.spec, m=rec.spec.bounds[-1], n=rec.B.ncols)
            rec.entry.executable = merge
        parts = tuple(r.C for r in shard_results)
        with tel.span("shard_merge", uid=rec.uid, n_shards=spec.n_shards):
            if self.mesh is not None:
                # Mesh placement commits each shard's result to its shard
                # device; one jitted computation can't mix committed
                # devices, so gather the parts home first.
                home = next(iter(parts[0].val.devices()))
                parts = tuple(C if C.val.devices() == {home}
                              else jax.device_put(C, home) for C in parts)
            C = merge(parts)
        timings: Dict[str, float] = {}
        for r in shard_results:
            for k, v in r.timings.items():
                timings[k] = timings.get(k, 0.0) + v
        # Book only the merge/verify overhead on the parent plan — the
        # shard work is already charged to the shard plans, and the
        # overhead-vs-shard-work split is exactly what an adaptive shard
        # count would tune on.
        rec.entry.stats.time_s += time.perf_counter() - t_fin
        return SpgemmResult(
            C=C,
            total_nprod=sum(r.total_nprod for r in shard_results),
            total_nnz=sum(r.total_nnz for r in shard_results),
            sym_binning=None, num_binning=None, timings=timings)

    def _note_estimate_confirmed(self, uid: int) -> None:
        """One ADMITTED finalize just verified an estimated plan: count
        the hit and let the engine-level estimator headroom decay toward
        its floor (sustained accuracy should not keep paying day-one
        conservatism)."""
        self.stats.estimate_hits += 1
        self.est_state.note_hit()
        self.telemetry.event("estimate_confirmed", uid=uid,
                             est_headroom=self.est_state.headroom)

    def _note_hash_admit(self, rec: _Pending, sym_sizes, sym_fall,
                         num_sizes=None, num_fall=0) -> None:
        """Adaptive-headroom telemetry for one ADMITTED hash finalize.

        Folds the bin sizes the verify sync already fetched into the
        plan's policy state (streak maxima — capture is free, no extra
        sync).  Once the eviction-free streak reaches the policy
        threshold, re-derive the schedule from the observed maxima at a
        shrunken headroom and swap it in iff that actually removes
        padded grid steps or whole rungs — ONE deliberate retrace that
        stops a stable stream paying for day-one jitter margins.  At most
        one trim fires per overflow epoch (``PolicyState.trimmed``).
        """
        entry = rec.entry
        plan = entry.plan      # CURRENT plan: maxima fold monotonically
        if plan.hash_schedule is None:
            return
        state = plan.policy or PolicyState(headroom=self.policy.headroom_init)
        if state.estimated:
            # First admitted finalize under an estimated schedule:
            # the prediction held — promote the plan to verified.
            self._note_estimate_confirmed(rec.uid)
            state = state.with_estimated(False)
        state = state.note_admit(sym_sizes, sym_fall, num_sizes, num_fall)
        if state.wants_trim(self.policy):
            trimmed = autotune.trim_schedule(
                state, plan.hash_schedule, m=plan.a_sig.nrows,
                sym_ladder=plan.sym_ladder, packed=plan.config.row_packing,
                fused=plan.config.fuse_numeric, policy=self.policy)
            state = state.after_trim(self.policy)
            if trimmed is not None:
                self.stats.schedule_trims += 1
                entry.stats.schedule_trims += 1
                self.telemetry.event("schedule_trim", uid=rec.uid,
                                     headroom=state.headroom)
                self.cache.specialize(entry, plan.with_hash_schedule(
                    HashSchedule(*trimmed)).with_policy(state))
                return
        self.cache.update_policy(entry, state)

    def _grow_and_redo(self, rec: _Pending, total_nprod: int,
                       total_nnz: int, *,
                       schedule_overflow: bool = False) -> SpgemmResult:
        """Overflow recovery (rare: a same-signature request outgrew the
        learned plan).  Grow the buckets, redo via the steps path, and
        re-specialize the entry so the NEXT request is hot again.

        ``schedule_overflow`` marks a hash BIN-SCHEDULE overflow (a rung
        or fallback capacity evicted rows) — the only signal the adaptive
        headroom tracks.  A pure nnz/prod capacity overflow with an
        admitting schedule grows the pow-2 buckets but must NOT inflate
        the bin headroom: the bins never jittered."""
        plan = rec.plan
        self.stats.capacity_grows += 1
        rec.entry.stats.capacity_grows += 1
        tel = self.telemetry
        tel.event("capacity_grow", uid=rec.uid,
                  schedule_overflow=schedule_overflow,
                  total_nprod=total_nprod, total_nnz=total_nnz)
        # NB: an overflowed hot run truncates its expansion (or drops rows
        # past a bin bucket), so its totals are only lower bounds; the
        # steps redo reports the true capacities to respecialize with.
        # Floor at the entry's CURRENT buckets so a concurrent grow is kept.
        current = rec.entry.plan
        grown = plan.with_capacities(
            max(plan.prod_bucket, current.prod_bucket or 0,
                next_bucket(max(total_nprod, 1))),
            max(plan.nnz_bucket, current.nnz_bucket or 0,
                next_bucket(max(total_nnz, 1))))
        # Tracked-jitter headroom: the stream just proved it jitters more
        # than the schedule allowed — the redo re-derives with a grown
        # headroom (and a fresh streak/trim epoch).
        state = current.policy or PolicyState(
            headroom=self.policy.headroom_init)
        if state.estimated:
            # An estimated plan under-provisioned: the steps redo below
            # re-derives EXACT buckets (clearing the provenance flag),
            # and the engine-level estimator headroom grows so the next
            # cold estimate is more conservative.
            self.stats.estimate_misses += 1
            self.est_state.note_miss()
            tel.event("estimate_miss", uid=rec.uid,
                      schedule_overflow=schedule_overflow)
            state = state.with_estimated(False)
        if schedule_overflow:
            state = state.note_overflow(self.policy)
        grown = grown.with_policy(state)
        with tel.span("grow_redo", uid=rec.uid):
            result, prod_cap, nnz_cap, hash_sched = _execute_steps(
                rec.A, rec.B, grown,
                StepTimer(False, tracer=tel, uid=rec.uid),
                headroom=state.headroom)
        rec.entry.stats.steps_calls += 1   # the redo ran the steps oracle
        respecialized = grown.with_capacities(prod_cap, nnz_cap)
        if hash_sched is not None:
            # The redo floored at the DISPATCH plan's schedule; union with
            # the entry's CURRENT one so a concurrent grow is kept too.
            if current.hash_schedule is not None:
                hash_sched = hash_sched.union(current.hash_schedule)
            respecialized = respecialized.with_hash_schedule(hash_sched)
        self.cache.specialize(rec.entry, respecialized)
        rec.entry.stats.time_s += time.perf_counter() - rec.t0
        return result


# ---------------------------------------------------------------------------
# The process-wide default engine behind ``repro.core.spgemm``.
# ---------------------------------------------------------------------------

_DEFAULT: Optional[SpgemmEngine] = None
_DEFAULT_LOCK = threading.Lock()


def default_engine() -> SpgemmEngine:
    """Shared engine serving every ``spgemm()`` call in the process."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = SpgemmEngine()
        return _DEFAULT


def reset_default_engine() -> None:
    """Drop the shared engine (tests that need a cold cache)."""
    global _DEFAULT
    _DEFAULT = None
