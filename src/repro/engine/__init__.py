"""SpGEMM execution-plan engine: cached plans, batched executor, telemetry.

The reusable execution layer between the one-shot ``repro.core.spgemm``
API and the serving/analytics front-ends:

  plan.py      — immutable :class:`SpgemmPlan` over operand signatures
                 (everything derivable before data arrives).
  autotune.py  — :class:`AdaptivePolicy` / :class:`PolicyState`:
                 telemetry-driven shard-count selection (AUTO_SHARDS),
                 tracked-jitter hash-schedule headroom, and the
                 :class:`EstimatorState` calibration loop behind
                 ``plan_mode="estimate"`` cold planning.
  partition.py — :class:`ShardSpec` row-block partitioning (flop-balanced
                 bounds, pow-2 shard buckets) + mesh placement helpers.
  cache.py     — LRU :class:`PlanCache` of plans + jitted executables
                 (hit/miss/evict counters; the §5.4 recompile analog),
                 with JSON ``dump``/``load`` cross-process persistence.
  executor.py  — :class:`SpgemmEngine`: streaming submit/drain with
                 plan-grouped batching, completion-order finalize, and
                 sharded fan-out; ``execute`` backs ``spgemm()``.
  stats.py     — trace accounting and registry-backed engine/plan
                 counters (one source of truth with telemetry.py).
  telemetry.py — structured spans, metrics registry, ring-buffer event
                 log, and the JSONL / Chrome trace_event / Prometheus
                 exporters.

Lifecycle::

    signature -> plan (cold) -> first execution learns capacity buckets
              -> specialized plan + jitted executable cached
              -> steady-state requests: pad to bucket, dispatch async,
                 one verify sync; overflow grows buckets and re-plans.
    shards=N  -> parent plan learns a flop-balanced ShardSpec; requests
                 fan out into per-shard sub-dispatches (ordinary plans on
                 the slice signatures) and a jitted merge concatenation.
"""
from repro.core.spgemm import AUTO_SHARDS
from repro.core.workspace import (Arena, ArenaPressureError, Lease,
                                  LeaseSpec, default_arena,
                                  reset_default_arena)

from .autotune import (AdaptivePolicy, EstimatorState, MemoryGovernor,
                       PolicyState, choose_shards, revise_shards,
                       trim_schedule)
from .cache import CacheEntry, PlanCache
from .executor import (SpgemmEngine, SpgemmRequest, StepTimer,
                       default_engine, reset_default_engine)
from .partition import (ShardSpec, balanced_bounds, clamp_shards,
                        plan_shards, shard_devices)
from .plan import (HashSchedule, MatrixSig, PlanKey, SpgemmPlan, plan,
                   plan_key)
from .stats import (EngineStats, PlanStats, plan_label, render,
                    total_traces, traces_for)
from .telemetry import (LATENCY_BUCKETS_S, EventLog, MetricsRegistry, Span,
                        Telemetry, engine_sample_blocks, git_rev,
                        histogram_quantile, merge_sample_blocks,
                        prometheus_text, resolve_telemetry, utc_now_iso,
                        validate_chrome_trace)

__all__ = [
    "AUTO_SHARDS", "AdaptivePolicy", "EstimatorState", "PolicyState",
    "choose_shards", "revise_shards", "trim_schedule",
    "Arena", "ArenaPressureError", "Lease", "LeaseSpec", "MemoryGovernor",
    "default_arena", "reset_default_arena",
    "CacheEntry", "PlanCache", "SpgemmEngine", "SpgemmRequest", "StepTimer",
    "default_engine", "reset_default_engine", "ShardSpec", "balanced_bounds",
    "clamp_shards", "plan_shards", "shard_devices", "HashSchedule",
    "MatrixSig", "PlanKey", "SpgemmPlan", "plan", "plan_key", "EngineStats",
    "PlanStats", "plan_label", "render", "total_traces", "traces_for",
    "LATENCY_BUCKETS_S", "EventLog", "MetricsRegistry", "Span", "Telemetry",
    "engine_sample_blocks", "git_rev", "histogram_quantile",
    "merge_sample_blocks", "prometheus_text", "resolve_telemetry",
    "utc_now_iso", "validate_chrome_trace",
]
