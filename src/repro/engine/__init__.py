"""SpGEMM execution-plan engine: cached plans, batched executor, telemetry.

The reusable execution layer between the one-shot ``repro.core.spgemm``
API and the serving/analytics front-ends:

  plan.py      — immutable :class:`SpgemmPlan` over operand signatures
                 (everything derivable before data arrives).
  cache.py     — LRU :class:`PlanCache` of plans + jitted executables
                 (hit/miss/evict counters; the §5.4 recompile analog).
  executor.py  — :class:`SpgemmEngine`: streaming submit/drain with
                 plan-grouped batching and double-buffered host/device
                 overlap; ``execute`` backs ``spgemm()``.
  stats.py     — trace accounting and per-plan telemetry.

Lifecycle::

    signature -> plan (cold) -> first execution learns capacity buckets
              -> specialized plan + jitted executable cached
              -> steady-state requests: pad to bucket, dispatch async,
                 one verify sync; overflow grows buckets and re-plans.
"""
from .cache import CacheEntry, PlanCache
from .executor import (SpgemmEngine, SpgemmRequest, StepTimer,
                       default_engine, reset_default_engine)
from .plan import (HashSchedule, MatrixSig, PlanKey, SpgemmPlan, plan,
                   plan_key)
from .stats import EngineStats, PlanStats, render, total_traces, traces_for

__all__ = [
    "CacheEntry", "PlanCache", "SpgemmEngine", "SpgemmRequest", "StepTimer",
    "default_engine", "reset_default_engine", "HashSchedule", "MatrixSig",
    "PlanKey", "SpgemmPlan", "plan", "plan_key", "EngineStats", "PlanStats",
    "render", "total_traces", "traces_for",
]
