"""Structured tracing & metrics for the SpGEMM engine (spans + exporters).

OpSparse argues its systems wins through per-phase timing breakdowns
(§6.3: setup, symbolic, numeric, allocation overlap).  The engine's
observables were ad-hoc module counters (``engine/stats.py``) and a
coarse benchmark blob; this module gives them structure:

:class:`Telemetry`
    One handle per engine bundling a span tracer, a
    :class:`MetricsRegistry`, and a bounded :class:`EventLog` ring
    buffer.  Disabled by default (``enabled=False``): every record call
    returns immediately and the hot path stays sync-free — the engine
    only ever times device work at span boundaries that already host-
    sync (the finalize verify sync), so enabling spans never adds
    fences to the zero-retrace steady state.

Spans
    Wall-clock intervals with explicit parent/child links (``span_id``/
    ``parent_id``) and a request ``uid``, so nesting survives the
    completion-order drain reordering requests and the sharded fan-out
    splitting one request across sub-dispatches.  Synchronous nesting
    uses a thread-local stack (``with tel.span(...)``); the async
    dispatch->finalize split carries the open request span on the
    engine's pending record and closes it at finalize.

Metrics
    Counters, gauges, and histograms with fixed pow-2 latency buckets
    (:data:`LATENCY_BUCKETS_S`).  ``engine/stats.py``'s ``EngineStats``
    and ``PlanStats`` are registry-backed views over these counters —
    one source of truth, not a parallel set of fields.

Exporters
    ``export_jsonl`` (one JSON object per line), ``export_chrome_trace``
    (Chrome ``trace_event`` JSON loadable in Perfetto /
    ``chrome://tracing``; spans become ``"X"`` complete events on a
    per-request track), and :func:`prometheus_text` (Prometheus
    exposition text for the future serving front-end).

This module deliberately imports neither JAX nor anything from the
engine package, so stats/cache/executor can all depend on it freely.
"""
from __future__ import annotations

import bisect
import itertools
import json
import os
import subprocess
import threading
import time
from collections import deque
from datetime import datetime, timezone
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple, Union

# Fixed pow-2 latency bucket edges, in seconds: 2^-14 s (~61 us) .. 2^6 s
# (64 s).  Pow-2 edges mirror every other capacity in the engine — a
# latency that moves one bucket is a real regime change, not jitter.
LATENCY_BUCKETS_S: Tuple[float, ...] = tuple(2.0 ** e for e in range(-14, 7))


# ---------------------------------------------------------------------------
# Metrics: counters, gauges, pow-2 histograms, and their registry.
# ---------------------------------------------------------------------------

class Counter:
    """Monotone (by convention) numeric metric; ``value`` is plain host
    Python int/float, so accumulating device scalars can't wrap."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0

    def inc(self, v=1):
        self.value += v


class Gauge:
    """Point-in-time numeric metric (peaks, sizes)."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = 0

    def set(self, v):
        self.value = v


class Histogram:
    """Fixed-bucket histogram (pow-2 latency edges by default).

    ``counts[i]`` counts observations with ``v <= buckets[i]`` (and above
    the previous edge); ``counts[-1]`` is the +Inf overflow bucket.
    """

    __slots__ = ("buckets", "counts", "sum", "count")
    kind = "histogram"

    def __init__(self, buckets: Iterable[float] = LATENCY_BUCKETS_S):
        self.buckets = tuple(sorted(float(b) for b in buckets))
        assert self.buckets, "histogram needs at least one bucket edge"
        self.counts = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Name -> metric map with get-or-create accessors and a Prometheus
    text renderer.  One per :class:`Telemetry` (and hence per engine)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: "Dict[str, object]" = {}  # guarded-by: _lock

    def _get_or_create(self, name: str, factory):
        with self._lock:
            metric = self._metrics.get(name)
            if metric is None:
                metric = self._metrics[name] = factory()
            return metric

    def counter(self, name: str) -> Counter:
        metric = self._get_or_create(name, Counter)
        assert isinstance(metric, Counter), name
        return metric

    def gauge(self, name: str) -> Gauge:
        metric = self._get_or_create(name, Gauge)
        assert isinstance(metric, Gauge), name
        return metric

    def histogram(self, name: str,
                  buckets: Iterable[float] = LATENCY_BUCKETS_S) -> Histogram:
        metric = self._get_or_create(name, lambda: Histogram(buckets))
        assert isinstance(metric, Histogram), name
        return metric

    def get(self, name: str):
        return self._metrics.get(name)

    def snapshot(self) -> Dict[str, dict]:
        """JSON-ready view of every metric (tests, JSONL footers)."""
        out: Dict[str, dict] = {}
        with self._lock:
            items = list(self._metrics.items())
        for name, m in items:
            if isinstance(m, Histogram):
                out[name] = {"kind": m.kind, "buckets": list(m.buckets),
                             "counts": list(m.counts), "sum": m.sum,
                             "count": m.count}
            else:
                out[name] = {"kind": m.kind, "value": m.value}
        return out

    def render_lines(self, labels: str = "") -> List[str]:
        """Prometheus exposition lines for every registered metric.

        ``labels`` (e.g. ``plan="64x64·64x64/esc"``) is merged into each
        sample; histogram ``le`` labels compose with it.
        """
        lines: List[str] = []
        with self._lock:
            items = sorted(self._metrics.items())
        for name, m in items:
            lines.append(f"# TYPE {name} {m.kind}")
            lines.extend(render_metric_samples(name, m, labels))
        return lines

    def render_prometheus(self) -> str:
        return "\n".join(self.render_lines()) + "\n"

    def sample_blocks(self, labels: str = ""
                      ) -> "Dict[str, Tuple[str, List[str]]]":
        """``name -> (kind, sample lines)`` for every metric, with
        ``labels`` merged into each sample.  Blocks from several
        registries (one per tenant engine, say) merge under a single
        TYPE header per name via :func:`merge_sample_blocks` — repeated
        TYPE lines are invalid exposition text."""
        out: "Dict[str, Tuple[str, List[str]]]" = {}
        with self._lock:
            items = sorted(self._metrics.items())
        for name, m in items:
            out[name] = (m.kind, render_metric_samples(name, m, labels))
        return out


def _labelset(*parts: str) -> str:
    inner = ",".join(p for p in parts if p)
    return "{" + inner + "}" if inner else ""


def render_metric_samples(name: str, metric, labels: str = "") -> List[str]:
    """Sample lines (no TYPE header) for one metric — shared by the
    registry renderer and the per-plan renderer in :func:`prometheus_text`
    (which must emit each TYPE header once across many label sets)."""
    if isinstance(metric, Histogram):
        lines = []
        cum = 0
        for edge, c in zip(metric.buckets, metric.counts):
            cum += c
            le = 'le="%g"' % edge
            lines.append(f"{name}_bucket{_labelset(labels, le)} {cum}")
        le_inf = 'le="+Inf"'
        lines.append(f"{name}_bucket{_labelset(labels, le_inf)} "
                     f"{metric.count}")
        lines.append(f"{name}_sum{_labelset(labels)} {metric.sum:g}")
        lines.append(f"{name}_count{_labelset(labels)} {metric.count}")
        return lines
    return [f"{name}{_labelset(labels)} {metric.value:g}"
            if isinstance(metric.value, float)
            else f"{name}{_labelset(labels)} {metric.value}"]


def histogram_quantile(hist: Optional[Histogram], q: float
                       ) -> Optional[float]:
    """Conservative quantile estimate from a fixed-bucket histogram.

    Returns the smallest bucket upper edge whose cumulative count covers
    a ``q`` fraction of observations — the Prometheus
    ``histogram_quantile`` discipline, rounded UP to the edge, which is
    the right bias for deadline admission (over-predicting latency sheds
    a request early; under-predicting wastes its whole budget).  Empty
    or missing histograms return ``None`` (caller must admit blind);
    observations landing in the +Inf overflow bucket resolve to twice
    the top edge as a finite pessimistic stand-in.
    """
    if hist is None or not hist.count:
        return None
    target = max(0.0, min(1.0, q)) * hist.count
    cum = 0
    for edge, c in zip(hist.buckets, hist.counts):
        cum += c
        if cum >= target:
            return edge
    return 2.0 * hist.buckets[-1]


def merge_sample_blocks(
        blocks_list: "Iterable[Dict[str, Tuple[str, List[str]]]]") -> str:
    """Merge per-source sample blocks into one exposition document.

    Each source (a tenant engine, the service's own registry) renders
    its samples with its own label set; this emits ONE ``# TYPE`` header
    per metric name followed by every source's samples for that name.
    """
    merged: "Dict[str, Tuple[str, List[str]]]" = {}
    for blocks in blocks_list:
        for name, (kind, samples) in blocks.items():
            have = merged.get(name)
            if have is None:
                merged[name] = (kind, list(samples))
            else:
                have[1].extend(samples)
    lines: List[str] = []
    for name in sorted(merged):
        kind, samples = merged[name]
        lines.append(f"# TYPE {name} {kind}")
        lines.extend(samples)
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Spans and the bounded event log.
# ---------------------------------------------------------------------------

class Span:
    """One wall-clock interval with explicit parentage.

    Usable as a context manager (pushes onto the telemetry's thread-local
    stack so inner spans nest under it) or held open across async
    boundaries and closed with :meth:`Telemetry.end_span` — the engine
    keeps each request's span on its pending record until finalize.
    """

    __slots__ = ("_tel", "name", "span_id", "parent_id", "uid", "t0", "t1",
                 "attrs")

    def __init__(self, tel: "Telemetry", name: str, span_id: int,
                 parent_id: Optional[int], uid: Optional[int], t0: float,
                 attrs: dict):
        self._tel = tel
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.uid = uid
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs = attrs

    @property
    def dur(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self._tel._push(self)
        return self

    def __exit__(self, *exc) -> bool:
        self._tel._pop(self)
        self._tel.end_span(self)
        return False

    def to_dict(self) -> dict:
        return {"type": "span", "name": self.name, "span_id": self.span_id,
                "parent_id": self.parent_id, "uid": self.uid,
                "t0": self.t0, "t1": self.t1, "dur": self.dur,
                "attrs": dict(self.attrs)}


class _NullSpan:
    """The disabled-mode span: a shared, attribute-frozen no-op."""

    __slots__ = ()
    name = None
    span_id = None
    parent_id = None
    uid = None
    t0 = 0.0
    t1 = None
    dur = 0.0

    def set(self, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class EventLog:
    """Bounded ring buffer of telemetry records with overflow accounting:
    the oldest record is dropped when full, and ``dropped`` says how many
    were lost (silent truncation would read as "covered everything")."""

    def __init__(self, capacity: int = 4096):
        self.capacity = max(1, int(capacity))
        self.appended = 0  # guarded-by: _lock
        self._lock = threading.Lock()
        self._buf: deque = deque(maxlen=self.capacity)  # guarded-by: _lock

    def append(self, item) -> None:
        """Append a record: a dict, or a closed :class:`Span` (kept as-is
        and rendered to a dict lazily at :meth:`snapshot` — dict-building
        is the dominant per-span cost on the engine hot path)."""
        with self._lock:
            self.appended += 1
            self._buf.append(item)

    @property
    def dropped(self) -> int:
        return self.appended - len(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    def snapshot(self) -> List[dict]:
        with self._lock:
            items = list(self._buf)
        return [it.to_dict() if isinstance(it, Span) else it for it in items]

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.appended = 0


# ---------------------------------------------------------------------------
# The telemetry handle.
# ---------------------------------------------------------------------------

class Telemetry:
    """Tracer + metrics registry + event ring buffer for one engine.

    ``enabled=False`` (the default the engine resolves to) makes every
    span/event call a no-op returning the shared :data:`NULL_SPAN` —
    the metrics registry still works (the engine's counters are backed
    by it), but nothing is recorded and no clock is read.
    """

    def __init__(self, enabled: bool = True, *, events_capacity: int = 4096,
                 registry: Optional[MetricsRegistry] = None):
        self.enabled = bool(enabled)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.events = EventLog(events_capacity)
        self._ids = itertools.count(1)
        self._tls = threading.local()

    # -- span stack (thread-local synchronous nesting) ----------------------
    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        stack = self._stack()
        if stack and stack[-1] is span:
            stack.pop()

    def current_span(self) -> Optional[Span]:
        stack = self._stack()
        return stack[-1] if stack else None

    # -- recording ----------------------------------------------------------
    def span(self, name: str, *, parent: Optional[Span] = None,
             uid: Optional[int] = None, **attrs):
        """Open a span.  With no explicit ``parent`` the current thread's
        innermost ``with``-span is the parent; ``uid`` defaults to the
        parent's.  Use as a context manager for synchronous work, or keep
        the handle and :meth:`end_span` it later (async finalize)."""
        if not self.enabled:
            return NULL_SPAN
        if parent is None or parent is NULL_SPAN:
            parent = self.current_span()
        return Span(self, name, next(self._ids),
                    parent.span_id if parent is not None else None,
                    uid if uid is not None
                    else (parent.uid if parent is not None else None),
                    time.perf_counter(), attrs)

    # ``start_span`` is the explicit-lifetime alias (no with-block).
    start_span = span

    def end_span(self, span, **attrs) -> None:
        """Close an open span and commit it to the event log (idempotent;
        no-op for the disabled-mode NULL span)."""
        if span is NULL_SPAN or not isinstance(span, Span):
            return
        if span.t1 is not None:
            return
        span.t1 = time.perf_counter()
        if attrs:
            span.attrs.update(attrs)
        self.events.append(span)

    def event(self, name: str, *, uid: Optional[int] = None, **attrs) -> None:
        """Record a point event (overflow, trim, policy decision, ...)."""
        if not self.enabled:
            return
        self.events.append({"type": "event", "name": name,
                            "t": time.perf_counter(), "uid": uid,
                            "attrs": attrs})

    # -- views ---------------------------------------------------------------
    def finished_spans(self) -> List[dict]:
        return [e for e in self.events.snapshot() if e.get("type") == "span"]

    # -- exporters ------------------------------------------------------------
    def export_jsonl(self, path) -> int:
        """Write the event log as JSON Lines; returns lines written."""
        items = self.events.snapshot()
        with open(path, "w") as f:
            for item in items:
                f.write(json.dumps(item, default=str) + "\n")
        return len(items)

    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` payload (Perfetto / ``chrome://tracing``).

        Spans become ``"X"`` complete events with microsecond timestamps
        rebased to the earliest record; each request uid gets its own
        ``tid`` track (engine-level spans ride track 0), so cold vs
        steady requests and the sharded fan-out are visually separable.
        Explicit ``span_id``/``parent_id`` ride in ``args``.
        """
        items = self.events.snapshot()
        t_min = min((it.get("t0", it.get("t", 0.0)) for it in items),
                    default=0.0)

        def us(t):
            return round((t - t_min) * 1e6, 3)

        trace_events = []
        for it in items:
            tid = it.get("uid")
            tid = 0 if tid is None else int(tid) + 1
            if it.get("type") == "span":
                trace_events.append({
                    "name": it["name"], "ph": "X", "ts": us(it["t0"]),
                    "dur": round(max(it["dur"], 0.0) * 1e6, 3),
                    "pid": 1, "tid": tid,
                    "args": {"span_id": it["span_id"],
                             "parent_id": it["parent_id"],
                             "uid": it["uid"], **it["attrs"]}})
            else:
                trace_events.append({
                    "name": it["name"], "ph": "i", "ts": us(it["t"]),
                    "s": "t", "pid": 1, "tid": tid,
                    "args": {"uid": it.get("uid"), **it["attrs"]}})
        return {"traceEvents": trace_events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path) -> dict:
        payload = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        return payload


def resolve_telemetry(arg: Union["Telemetry", bool, None]) -> "Telemetry":
    """Engine-constructor sugar: ``None``/``False`` -> a fresh disabled
    handle (per-engine, so registries never alias), ``True`` -> a fresh
    enabled one, a :class:`Telemetry` -> itself."""
    if isinstance(arg, Telemetry):
        return arg
    return Telemetry(enabled=bool(arg))


# A shared do-nothing handle for call sites that only *emit* (events from
# the cache/partitioner when no engine telemetry was threaded through).
# Never hand its registry to stats objects — it is process-global.
NULL = Telemetry(enabled=False, events_capacity=1)


# ---------------------------------------------------------------------------
# Chrome trace_event schema validation (CI gate + tests).
# ---------------------------------------------------------------------------

_ALLOWED_PH = {"X", "B", "E", "i", "I", "M", "C"}


def validate_chrome_trace(payload_or_path) -> int:
    """Validate a Chrome ``trace_event`` payload; returns the event count.

    Checks the JSON-object container shape, per-event required fields,
    known phase types, non-negative ``dur`` on ``"X"`` complete events,
    and matched ``B``/``E`` begin/end pairs per ``(pid, tid)`` track.
    Raises :class:`ValueError` on the first violation.
    """
    payload = payload_or_path
    if isinstance(payload, (str, Path)):
        with open(payload) as f:
            payload = json.load(f)
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        raise ValueError("trace payload must be an object with 'traceEvents'")
    events = payload["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    open_be: Dict[tuple, int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event {i} missing '{field}'")
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"event {i} 'ts' is not numeric")
        ph = ev["ph"]
        if ph not in _ALLOWED_PH:
            raise ValueError(f"event {i} has unknown phase {ph!r}")
        track = (ev["pid"], ev["tid"])
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"event {i} ('X') needs numeric dur >= 0")
        elif ph == "B":
            open_be[track] = open_be.get(track, 0) + 1
        elif ph == "E":
            depth = open_be.get(track, 0)
            if depth <= 0:
                raise ValueError(f"event {i}: 'E' without matching 'B' "
                                 f"on track {track}")
            open_be[track] = depth - 1
    unbalanced = {k: v for k, v in open_be.items() if v}
    if unbalanced:
        raise ValueError(f"unmatched 'B' events on tracks {unbalanced}")
    return len(events)


# ---------------------------------------------------------------------------
# Trajectory-artifact helpers (BENCH_engine.json comparability).
# ---------------------------------------------------------------------------

# Exact timestamp format written to BENCH_engine.json (documented in the
# README): timezone-aware UTC ISO-8601 with seconds precision and the
# literal 'Z' suffix.
UTC_TIMESTAMP_FORMAT = "%Y-%m-%dT%H:%M:%SZ"


def utc_now_iso() -> str:
    """Timezone-aware UTC timestamp in :data:`UTC_TIMESTAMP_FORMAT`."""
    return datetime.now(timezone.utc).strftime(UTC_TIMESTAMP_FORMAT)


def git_rev(cwd=None) -> str:
    """Short git revision of ``cwd`` (or $PWD), ``"unknown"`` off-repo —
    stamped into benchmark artifacts for trajectory comparability."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=str(cwd) if cwd is not None else None,
            stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
            timeout=10, check=True)
        rev = out.stdout.decode().strip()
        return rev or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


# ---------------------------------------------------------------------------
# Prometheus endpoint rendering for a whole engine.
# ---------------------------------------------------------------------------

def engine_sample_blocks(engine, labels: str = ""
                         ) -> "Dict[str, Tuple[str, List[str]]]":
    """Sample blocks (``name -> (kind, lines)``) for one engine.

    Combines the engine registry (EngineStats counters, latency
    histograms), plan-cache counters, per-plan counters labeled by plan,
    and event-log accounting, with ``labels`` (e.g. ``tenant="acme"``)
    merged into every sample.  The serving front-end merges one block
    set per tenant engine into a single scrape document via
    :func:`merge_sample_blocks`.
    """
    tel = engine.telemetry
    cache = engine.cache
    # Arena gauges (opsparse_arena_bytes_in_use, _bytes_reserved,
    # _peak_bytes, _lease_{hits,misses}_total, _pressure_events_total)
    # are snapshot-set from the shared arena's accounting; refresh them
    # so a scrape of an engine idle since its last lease sees current
    # numbers, not lease-transition-time ones.
    refresh = getattr(engine, "_update_arena_gauges", None)
    if refresh is not None:
        refresh()
    blocks = tel.registry.sample_blocks(labels)

    for name, kind, value in (
            ("opsparse_plan_cache_hits_total", "counter", cache.hits),
            ("opsparse_plan_cache_misses_total", "counter", cache.misses),
            ("opsparse_plan_cache_evictions_total", "counter",
             cache.evictions),
            ("opsparse_plan_cache_size", "gauge", len(cache)),
            ("opsparse_plan_cache_capacity", "gauge", cache.capacity),
            ("opsparse_telemetry_events_appended_total", "counter",
             tel.events.appended),
            ("opsparse_telemetry_events_dropped_total", "counter",
             tel.events.dropped),
    ):
        blocks.setdefault(name, (kind, []))[1].append(
            f"{name}{_labelset(labels)} {value}")

    # Per-plan counters: a sample per plan label under one shared name.
    entries = list(cache.items())
    if entries:
        from .stats import PlanStats, plan_label  # local: stats imports us
        for _, entry in entries:
            label = ",".join(p for p in (
                labels, f'plan="{plan_label(entry.plan)}"') if p)
            for field in PlanStats._COUNTERS:
                name = entry.stats.metric_name(field)
                blocks.setdefault(name, ("counter", []))[1].extend(
                    render_metric_samples(
                        name, entry.stats.metric(field), label))
    return blocks


def prometheus_text(engine) -> str:
    """Prometheus exposition text for one :class:`SpgemmEngine` (the
    single-tenant view: :func:`engine_sample_blocks` with no labels).
    This is the text a serving front-end's ``/metrics`` endpoint returns
    verbatim; the multi-tenant service merges labeled blocks instead."""
    return merge_sample_blocks([engine_sample_blocks(engine)])
