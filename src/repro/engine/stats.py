"""Engine telemetry: trace accounting and registry-backed counters.

The paper reports its systems wins (alloc/exec overlap, metadata
minimization) through per-step timing breakdowns (§6.3); the engine's
analogous observables are *traces* (each one is a recompile — the
cudaMalloc-analog cost), plan-cache hit rates, and capacity-bucket growth
events.  Everything here is plain host-side bookkeeping surfaced to
``benchmarks/bench_engine.py`` and the regression tests.

Trace counting works by side effect: :func:`record_trace` is called in the
body of each per-plan jitted executable, so it runs exactly once per trace
(Python executes only while JAX is tracing) — repeat calls that hit the
compiled executable never touch it.  That gives the tests a direct "zero
retraces for a repeated shape" observable.  :func:`reset` clears the
module-global counters; ``tests/conftest.py`` runs it before every test so
trace-count assertions can't bleed across test files.

:class:`EngineStats` and :class:`PlanStats` keep their historical field
API (``stats.requests``, ``entry.stats.hot_calls``, ...) but every field
is now backed by a counter/gauge in a
:class:`~repro.engine.telemetry.MetricsRegistry` — the structured
telemetry layer and the legacy attribute reads see ONE set of numbers,
and the Prometheus exporter (:func:`repro.engine.telemetry.
prometheus_text`) renders them without a parallel bookkeeping path.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, Optional, Tuple

from .telemetry import MetricsRegistry

# -- trace accounting (module-global: jit caches are process-global too) ----

_TRACES: Dict = defaultdict(int)
_TOTAL = {"count": 0}


def record_trace(key) -> None:
    """Called from INSIDE a traced executable body — fires once per trace."""
    _TRACES[key] += 1
    _TOTAL["count"] += 1


def total_traces() -> int:
    """Process-wide count of engine hot-path traces (recompiles)."""
    return _TOTAL["count"]


def traces_for(key) -> int:
    return _TRACES.get(key, 0)


def reset() -> None:
    """Zero the process-wide trace counters (test isolation: the autouse
    fixture in ``tests/conftest.py`` calls this before every test)."""
    _TRACES.clear()
    _TOTAL["count"] = 0


# -- per-plan / per-engine counters ----------------------------------------

def plan_label(plan) -> str:
    """Compact stable label for one plan (Prometheus label values,
    telemetry event payloads): shapes, method, and the shard fan-out."""
    a, b = plan.a_sig, plan.b_sig
    label = (f"{a.nrows}x{a.ncols}·{b.nrows}x{b.ncols}"
             f"/{plan.config.method}")
    if plan.config.shards != 1:
        label += f"/sh{plan.config.shards}"
    return label


def _metric_property(field: str):
    def fget(self):
        return self._metrics[field].value

    def fset(self, v):
        self._metrics[field].value = v

    return property(fget, fset, doc=f"registry-backed '{field}' counter")


class _RegistryStats:
    """Base for stats objects whose fields live in a MetricsRegistry.

    Subclasses declare ``_COUNTERS``/``_GAUGES`` field names plus a
    metric-name prefix; attribute get/set on those names routes to the
    registry metric, so ``stats.requests += 1`` and a Prometheus scrape
    read the same number.  ``_NAMES`` overrides the default
    ``<prefix><field>_total`` metric naming.
    """

    _COUNTERS: Tuple[str, ...] = ()
    _GAUGES: Tuple[str, ...] = ()
    _PREFIX = "opsparse_"
    _NAMES: Dict[str, str] = {}

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self._metrics = {}
        for field in self._COUNTERS:
            self._metrics[field] = self.registry.counter(
                self.metric_name(field))
        for field in self._GAUGES:
            self._metrics[field] = self.registry.gauge(
                self.metric_name(field))

    @classmethod
    def metric_name(cls, field: str) -> str:
        name = cls._NAMES.get(field)
        if name is not None:
            return name
        suffix = "_total" if field in cls._COUNTERS else ""
        return f"{cls._PREFIX}{field}{suffix}"

    def metric(self, field: str):
        return self._metrics[field]


class PlanStats(_RegistryStats):
    """Telemetry for one cached plan (fields are registry counters).

    calls           requests executed under this plan
    hot_calls       served by the jitted steady-state executable
    steps_calls     served by the host-orchestrated six-step path
    capacity_grows  bucket overflows that forced a re-plan
    bin_overflows   hash bin-count/fallback schedule overflows
    schedule_trims  headroom-policy schedule shrinks (autotune)
    time_s          wall-clock charged to this plan (seconds)
    """

    _PREFIX = "opsparse_plan_"
    _COUNTERS = ("calls", "hot_calls", "steps_calls", "capacity_grows",
                 "bin_overflows", "schedule_trims", "time_s")
    _NAMES = {"time_s": "opsparse_plan_time_seconds_total"}


class EngineStats(_RegistryStats):
    """Engine-level counters (cache counters live on the PlanCache).

    requests          user-visible requests (shard sub-dispatches excluded)
    overlapped        request k+1 planned while k ran on device
    capacity_grows    pow-2 bucket overflows (re-plan + retrace)
    bin_overflows     hash launch-schedule overflows (subset of grows)
    drains            drain() invocations
    sharded_requests  requests fanned out into row-block shards
    shard_grows       per-shard slice-storage bucket grows
    reordered         drain() finalizes ahead of dispatch order
    peak_inflight     max concurrent dispatches a drain() held (gauge)
    auto_requests     requests routed through AUTO_SHARDS policy
    policy_revisions  telemetry-driven shard-count re-decisions
    schedule_trims    headroom-policy hash-schedule shrinks
    arena_pressure    governor-cap lease refusals (degradation entered)
    arena_trims       forced headroom trims under arena pressure
    arena_spills      fused calls spilled to the unleased two-pass path
    estimates         cold plans specialized from the sampling estimator
    estimate_hits     estimated plans confirmed by an admitted finalize
    estimate_misses   estimated plans corrected by an overflow retrace
    faults_injected   scheduled FaultPlan injections this engine consumed
    """

    _PREFIX = "opsparse_engine_"
    _COUNTERS = ("requests", "overlapped", "capacity_grows", "bin_overflows",
                 "drains", "sharded_requests", "shard_grows", "reordered",
                 "auto_requests", "policy_revisions", "schedule_trims",
                 "arena_pressure", "arena_trims", "arena_spills",
                 "estimates", "estimate_hits", "estimate_misses",
                 "faults_injected")
    _GAUGES = ("peak_inflight",)


for _field in PlanStats._COUNTERS + PlanStats._GAUGES:
    setattr(PlanStats, _field, _metric_property(_field))
for _field in EngineStats._COUNTERS + EngineStats._GAUGES:
    setattr(EngineStats, _field, _metric_property(_field))
del _field


def render(engine) -> str:
    """Human-readable telemetry block for benchmarks/examples.

    A pure consumer of the structured layer: engine/plan counters come
    from the registry-backed stats, span/event accounting and latency
    quantiles from the engine's :class:`~repro.engine.telemetry.
    Telemetry`.  Defensive against empty state — zero requests, an
    unspecialized plan (no buckets/policy/schedule), or an empty cache
    must render, not divide by zero.
    """
    cache = engine.cache
    s = engine.stats
    lines = [
        "engine: %d requests, %d plans cached (cap %d)" % (
            s.requests, len(cache), cache.capacity),
        "plan cache: %d hits / %d misses / %d evictions (hit rate %.1f%%)" % (
            cache.hits, cache.misses, cache.evictions,
            100.0 * cache.hit_rate),
        "overlap: %d requests planned while predecessor executed" % s.overlapped,
        "recompiles: %d hot-path traces, %d capacity grows "
        "(%d hash bin overflows)" % (
            total_traces(), s.capacity_grows, s.bin_overflows),
        "sharding: %d sharded requests, %d per-shard bucket grows; "
        "drain reordered %d finalizes (peak %d in flight)" % (
            s.sharded_requests, s.shard_grows, s.reordered,
            s.peak_inflight),
        "policy: %d auto-shard requests, %d shard revisions, "
        "%d schedule trims" % (
            s.auto_requests, s.policy_revisions, s.schedule_trims),
    ]
    if s.faults_injected:
        lines.append("faults: %d scheduled injections consumed"
                     % s.faults_injected)
    if s.estimates:
        est = getattr(engine, "est_state", None)
        lines.append(
            "estimate: %d estimated plans, %d confirmed / %d retraced"
            % (s.estimates, s.estimate_hits, s.estimate_misses)
            + ("" if est is None
               else ", headroom %.2f" % est.headroom))
    arena = getattr(engine, "arena", None)
    if arena is not None:
        lines.append(
            "arena: %d B in use / %d B reserved (peak %d B), "
            "%d hits / %d misses, %d pressure events "
            "(%d trims, %d spills)" % (
                arena.bytes_in_use, arena.bytes_reserved, arena.peak_bytes,
                arena.lease_hits, arena.lease_misses, arena.pressure_events,
                s.arena_trims, s.arena_spills))
    tel = getattr(engine, "telemetry", None)
    if tel is not None and tel.enabled:
        spans = sum(1 for e in tel.events.snapshot()
                    if e.get("type") == "span")
        lines.append(
            "telemetry: %d events in ring (%d spans; %d of %d appended "
            "dropped)" % (len(tel.events), spans, tel.events.dropped,
                          tel.events.appended))
        hist = tel.registry.get("opsparse_request_latency_seconds")
        if hist is not None and getattr(hist, "count", 0):
            lines.append(
                "latency: %d finalized requests, mean %.2f ms" % (
                    hist.count, 1e3 * hist.mean))
    for key, entry in cache.items():
        ps = entry.stats
        p = entry.plan
        sched = ""
        if p.hash_schedule is not None:
            hs = p.hash_schedule
            sched = ", sched sym=%s num=%s" % (
                "/".join(str(b) for b in hs.sym_row_buckets),
                "/".join(str(b) for b in hs.num_row_buckets))
        if p.policy is not None:
            pol = p.policy
            sched += ", policy headroom=%.2f streak=%d" % (
                pol.headroom, pol.streak)
            if pol.shard_decision is not None:
                sched += " shards->%d" % pol.shard_decision
        if p.shard_spec is not None:
            sched += ", shards=%d bounds=%s caps=%s" % (
                p.shard_spec.n_shards,
                "/".join(str(b) for b in p.shard_spec.bounds),
                "/".join(str(c) for c in p.shard_spec.cap_buckets))
        lines.append(
            "  plan %s: %d calls (%d hot / %d steps), "
            "buckets prod=%s nnz=%s%s, %.1f ms total" % (
                plan_label(p), ps.calls, ps.hot_calls, ps.steps_calls,
                p.prod_bucket, p.nnz_bucket, sched, ps.time_s * 1e3))
    return "\n".join(lines)
