"""Engine telemetry: trace accounting and per-plan counters.

The paper reports its systems wins (alloc/exec overlap, metadata
minimization) through per-step timing breakdowns (§6.3); the engine's
analogous observables are *traces* (each one is a recompile — the
cudaMalloc-analog cost), plan-cache hit rates, and capacity-bucket growth
events.  Everything here is plain host-side bookkeeping surfaced to
``benchmarks/bench_engine.py`` and the regression tests.

Trace counting works by side effect: :func:`record_trace` is called in the
body of each per-plan jitted executable, so it runs exactly once per trace
(Python executes only while JAX is tracing) — repeat calls that hit the
compiled executable never touch it.  That gives the tests a direct "zero
retraces for a repeated shape" observable.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict

# -- trace accounting (module-global: jit caches are process-global too) ----

_TRACES: Dict = defaultdict(int)
_TOTAL = {"count": 0}


def record_trace(key) -> None:
    """Called from INSIDE a traced executable body — fires once per trace."""
    _TRACES[key] += 1
    _TOTAL["count"] += 1


def total_traces() -> int:
    """Process-wide count of engine hot-path traces (recompiles)."""
    return _TOTAL["count"]


def traces_for(key) -> int:
    return _TRACES.get(key, 0)


# -- per-plan / per-engine counters ----------------------------------------

@dataclasses.dataclass
class PlanStats:
    """Telemetry for one cached plan."""

    calls: int = 0            # requests executed under this plan
    hot_calls: int = 0        # served by the jitted steady-state executable
    steps_calls: int = 0      # served by the host-orchestrated six-step path
    capacity_grows: int = 0   # bucket overflows that forced a re-plan
    bin_overflows: int = 0    # hash bin-count/fallback schedule overflows
    schedule_trims: int = 0   # headroom-policy schedule shrinks (autotune)
    time_s: float = 0.0       # wall-clock charged to this plan


@dataclasses.dataclass
class EngineStats:
    """Engine-level counters (cache counters live on the PlanCache)."""

    requests: int = 0
    overlapped: int = 0       # request k+1 planned while k ran on device
    capacity_grows: int = 0
    bin_overflows: int = 0    # hash launch-schedule overflows (subset of grows)
    drains: int = 0
    sharded_requests: int = 0 # requests fanned out into row-block shards
    shard_grows: int = 0      # per-shard slice-storage bucket grows
    reordered: int = 0        # drain() finalizes ahead of dispatch order
    peak_inflight: int = 0    # max concurrent dispatches a drain() held
    auto_requests: int = 0    # requests routed through AUTO_SHARDS policy
    policy_revisions: int = 0 # telemetry-driven shard-count re-decisions
    schedule_trims: int = 0   # headroom-policy hash-schedule shrinks


def render(engine) -> str:
    """Human-readable telemetry block for benchmarks/examples."""
    cache = engine.cache
    s = engine.stats
    lines = [
        "engine: %d requests, %d plans cached (cap %d)" % (
            s.requests, len(cache), cache.capacity),
        "plan cache: %d hits / %d misses / %d evictions (hit rate %.1f%%)" % (
            cache.hits, cache.misses, cache.evictions,
            100.0 * cache.hit_rate),
        "overlap: %d requests planned while predecessor executed" % s.overlapped,
        "recompiles: %d hot-path traces, %d capacity grows "
        "(%d hash bin overflows)" % (
            total_traces(), s.capacity_grows, s.bin_overflows),
        "sharding: %d sharded requests, %d per-shard bucket grows; "
        "drain reordered %d finalizes (peak %d in flight)" % (
            s.sharded_requests, s.shard_grows, s.reordered,
            s.peak_inflight),
        "policy: %d auto-shard requests, %d shard revisions, "
        "%d schedule trims" % (
            s.auto_requests, s.policy_revisions, s.schedule_trims),
    ]
    for key, entry in cache.items():
        ps = entry.stats
        p = entry.plan
        sched = ""
        if p.hash_schedule is not None:
            hs = p.hash_schedule
            sched = ", sched sym=%s num=%s" % (
                "/".join(str(b) for b in hs.sym_row_buckets),
                "/".join(str(b) for b in hs.num_row_buckets))
        if p.policy is not None:
            pol = p.policy
            sched += ", policy headroom=%.2f streak=%d" % (
                pol.headroom, pol.streak)
            if pol.shard_decision is not None:
                sched += " shards->%d" % pol.shard_decision
        if p.shard_spec is not None:
            sched += ", shards=%d bounds=%s caps=%s" % (
                p.shard_spec.n_shards,
                "/".join(str(b) for b in p.shard_spec.bounds),
                "/".join(str(c) for c in p.shard_spec.cap_buckets))
        lines.append(
            "  plan %dx%d·%dx%d %s: %d calls (%d hot / %d steps), "
            "buckets prod=%s nnz=%s%s, %.1f ms total" % (
                p.a_sig.nrows, p.a_sig.ncols, p.b_sig.nrows, p.b_sig.ncols,
                p.config.method, ps.calls, ps.hot_calls, ps.steps_calls,
                p.prod_bucket, p.nnz_bucket, sched, ps.time_s * 1e3))
    return "\n".join(lines)
