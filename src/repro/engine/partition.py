"""Row-block partitioning: one plan, N shards.

Liu & Vinter's framework shows SpGEMM decomposes into independent
row-block sub-products — C[lo:hi] = A[lo:hi] · B — and the SpGEMM survey
identifies load-balanced row partitioning as the key scaling lever.  The
engine's flop-estimate machinery (``core/analysis.row_flops``) already
computes the balance weight per row, so a partition-aware plan carries a
:class:`ShardSpec`: N contiguous row blocks of A whose *cumulative* flop
estimates are even, with each block's row count and slice storage
bucketed to pow-2 so the per-shard sub-problems land on stable plan
signatures (and therefore hit the plan cache — two shards with the same
buckets share ONE plan and ONE executable).

The spec is learned on the cold call (the only host sync that reads the
whole flop vector) and then pinned: steady-state traffic in the same
shape bucket reuses the learned bounds, so shard signatures never move
and the per-shard executables stay hot.  Per-shard overflow (a slice
outgrowing its storage bucket) grows only that shard's bucket —
monotonically, like every other learned capacity in the engine.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.workspace import next_bucket
from repro.launch.mesh import data_axis_devices  # noqa: F401  (re-export)


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Learned row-block partition of A for one plan signature.

    bounds       n_shards+1 row boundaries (bounds[0]=0, bounds[-1]=M);
                 contiguous blocks balanced by cumulative flop estimate.
    row_buckets  pow-2 padded row count per shard — the static nrows of
                 the shard's A slice (padding rows are empty).
    cap_buckets  pow-2 col/val storage capacity per shard slice.
    """

    bounds: Tuple[int, ...]
    row_buckets: Tuple[int, ...]
    cap_buckets: Tuple[int, ...]

    @property
    def n_shards(self) -> int:
        return len(self.row_buckets)

    def rows(self, s: int) -> int:
        """Real (unpadded) row count of shard ``s``."""
        return self.bounds[s + 1] - self.bounds[s]

    def with_cap_bucket(self, s: int, cap: int) -> "ShardSpec":
        """Grown spec: shard ``s``'s storage bucket raised to ``cap``.

        Only that shard's signature moves — the other shards' plans (and
        their cached executables) are untouched."""
        caps = list(self.cap_buckets)
        caps[s] = max(caps[s], next_bucket(max(int(cap), 1)))
        return dataclasses.replace(self, cap_buckets=tuple(caps))

    def union(self, other: "ShardSpec") -> "ShardSpec":
        """Elementwise-max storage buckets over an identical partition —
        specs only ever grow (cross-process cache merges).  Specs with
        different bounds aren't comparable; keep ``self``."""
        if (other.bounds != self.bounds
                or other.row_buckets != self.row_buckets):
            return self
        return dataclasses.replace(self, cap_buckets=tuple(
            max(a, b) for a, b in zip(self.cap_buckets, other.cap_buckets)))


# A shard below this many rows cannot be cut further without empty
# blocks; the feasibility clamp the adaptive policy (engine/autotune)
# applies before pinning a shard-count decision.
MIN_SHARD_ROWS = 2


def clamp_shards(nrows: int, n: int) -> int:
    """Feasible shard count for an ``nrows``-row A: at least 1, at most
    one shard per ``MIN_SHARD_ROWS`` rows (``balanced_bounds`` keeps >=1
    real row per shard; this keeps the blocks worth slicing at all)."""
    return max(1, min(int(n), max(int(nrows) // MIN_SHARD_ROWS, 1)))


def balanced_bounds(weights: np.ndarray, n_shards: int) -> Tuple[int, ...]:
    """Contiguous row-block boundaries balancing cumulative ``weights``.

    Greedy prefix cuts at each multiple of total/n: block s ends at the
    first row whose cumulative weight reaches s·total/n, so no block
    exceeds total/n + max(row weight) — within 2x of the mean whenever no
    single row dominates.  Zero-total inputs fall back to an even row
    split.  Every shard keeps at least one row while rows remain.
    """
    m = int(len(weights))
    n = max(1, min(int(n_shards), m if m else 1))
    if m == 0:
        return (0,) * (n + 1)
    cum = np.cumsum(np.asarray(weights, dtype=np.int64))
    total = int(cum[-1])
    bounds = [0]
    for s in range(1, n):
        if total > 0:
            cut = int(np.searchsorted(cum, total * s / n, side="left")) + 1
        else:
            cut = (m * s) // n
        # Monotone, and leave >=1 row for each remaining shard.
        cut = max(bounds[-1] + 1, min(cut, m - (n - s)))
        bounds.append(cut)
    bounds.append(m)
    return tuple(bounds)


# Slice-storage buckets carry headroom over the cold call's observed nnz:
# same-signature traffic jitters within its pow-2 storage bucket, and a
# padded slice is orders of magnitude cheaper than the bucket grow (plan
# re-specialization + retrace) an overflow costs — the same memory-vs-
# retrace trade-off as the hash schedule's 2x.
_SLICE_HEADROOM = 2.0


def plan_shards(rpt: np.ndarray, flops: np.ndarray, n_shards: int, *,
                headroom: float = _SLICE_HEADROOM,
                telemetry=None) -> ShardSpec:
    """Derive a :class:`ShardSpec` from host-fetched row pointers and the
    per-row flop estimate (``core/analysis.row_flops``).

    ``telemetry`` (duck-typed: anything with ``.event``) records the
    pinned partition — this is the one decision per sharded plan, so the
    trace should show where the bounds came from."""
    rpt = np.asarray(rpt, dtype=np.int64)
    bounds = balanced_bounds(flops, n_shards)
    row_buckets = tuple(
        next_bucket(max(bounds[s + 1] - bounds[s], 1), minimum=1)
        for s in range(len(bounds) - 1))
    cap_buckets = tuple(
        next_bucket(max(int((rpt[bounds[s + 1]] - rpt[bounds[s]])
                            * headroom), 1))
        for s in range(len(bounds) - 1))
    if telemetry is not None:
        telemetry.event("partition.planned", n_shards=len(row_buckets),
                        bounds=bounds, cap_buckets=cap_buckets)
    return ShardSpec(bounds=bounds, row_buckets=row_buckets,
                     cap_buckets=cap_buckets)


def shard_devices(mesh, n_shards: int) -> tuple:
    """Round-robin shard -> device placement over the mesh's data axes
    (replicated B, row-sharded A)."""
    devs = data_axis_devices(mesh)
    return tuple(devs[s % len(devs)] for s in range(n_shards))
