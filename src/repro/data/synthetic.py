"""Deterministic, resumable synthetic data pipeline.

Every batch is a pure function of (seed, step) — the stream needs no
buffering, replays exactly after restart (the trainer checkpoints just the
step counter), and shards trivially across hosts (each host materializes
only its batch slice).

Token sequences follow a noisy affine recurrence t[i+1] = (a·t[i] + c) % V
with ``noise`` probability of a uniform resample — learnable structure so
the end-to-end examples show real loss curves, not flat noise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1
    mult: int = 3
    add: int = 7


class SyntheticTokenStream:
    """Stateless-resumable LM token stream."""

    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = step

    # -- checkpointable state -------------------------------------------
    def state(self) -> Dict:
        return {"step": self.step, "seed": self.cfg.seed}

    @classmethod
    def from_state(cls, cfg: DataConfig, state: Dict) -> "SyntheticTokenStream":
        assert state["seed"] == cfg.seed, "restoring stream with wrong seed"
        return cls(cfg, step=int(state["step"]))

    # -- batch generation -------------------------------------------------
    def _key(self, step: int):
        return jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), step)

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        key = self._key(step)
        k0, k1, k2 = jax.random.split(key, 3)
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        start = jax.random.randint(k0, (b, 1), 0, v)

        def rec(t):
            return (t * cfg.mult + cfg.add) % v

        toks = [start]
        for _ in range(s):
            toks.append(rec(toks[-1]))
        tokens = jnp.concatenate(toks, axis=1)              # (B, S+1)
        noise_mask = jax.random.bernoulli(k1, cfg.noise, tokens.shape)
        noise_tok = jax.random.randint(k2, tokens.shape, 0, v)
        return {"tokens": jnp.where(noise_mask, noise_tok, tokens)
                .astype(jnp.int32)}

    def next_batch(self) -> Dict[str, jax.Array]:
        batch = self.batch_at(self.step)
        self.step += 1
        return batch


def batch_for_arch(cfg: ArchConfig, data_cfg: DataConfig, step: int,
                   stream: Optional[SyntheticTokenStream] = None):
    """Arch-aware batch: adds vision embeddings / encoder features."""
    stream = stream or SyntheticTokenStream(data_cfg, step)
    key = jax.random.fold_in(jax.random.PRNGKey(data_cfg.seed + 1), step)
    if cfg.family == "encoder":
        b, s = data_cfg.global_batch, data_cfg.seq_len
        feats = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
        labels = (jnp.argmax(feats[..., :cfg.vocab_size], axis=-1)
                  ).astype(jnp.int32)
        return {"features": feats, "labels": labels}
    batch = stream.batch_at(step)
    if cfg.family == "vlm":
        b = data_cfg.global_batch
        batch["vision"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model), jnp.float32)
    return batch
