"""Gradient compression with error feedback (distributed-optimization trick).

Int8 symmetric quantization of gradients before the data-parallel
reduction, with per-tensor scales and an ERROR-FEEDBACK accumulator that
re-injects quantization residuals into the next step — the standard
convergence-preserving construction (1-bit Adam / EF-SGD lineage).

On the wire: with ``shard_map`` over the data axes the transmitted payload
is the int8 tensor + one f32 scale per tensor (4x less ICI traffic than
bf16 grads; the reduction itself runs in int32 to avoid overflow at up to
2^23 participants).  In this container the collective executes on the
virtual mesh; the payload accounting is what the roofline uses.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Tree = Any


def quantize(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array,
                                                    jax.Array]:
    """-> (q int8, scale f32 scalar, new_err).  Error feedback: quantize
    (g + err); the residual becomes the next step's err."""
    target = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(target)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    new_err = target - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(grads: Tree) -> Tree:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads: Tree, err_state: Tree):
    """Quantize a whole gradient tree; returns (q_tree, scale_tree, new_err)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = quantize(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    unf = jax.tree_util.tree_unflatten
    return unf(treedef, qs), unf(treedef, scales), unf(treedef, errs)


def decompress_tree(q_tree: Tree, scale_tree: Tree) -> Tree:
    return jax.tree_util.tree_map(dequantize, q_tree, scale_tree)


def compressed_psum(grads: Tree, err_state: Tree, axis_name: str):
    """Inside ``shard_map``: int8-payload mean over ``axis_name``.

    The reduction runs on int32 (sums of int8 fit up to 2^23 ranks); the
    per-tensor scale is maxed across ranks first so every rank quantizes
    onto the same grid and the sum is exact in the quantized domain.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(target)) / 127.0, 1e-12)
        scale = jax.lax.pmax(scale, axis_name)        # shared grid
        q = jnp.clip(jnp.round(target / scale), -127, 127)
        new_err = target - q * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
        mean = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
        return mean.astype(g.dtype), new_err

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    unf = jax.tree_util.tree_unflatten
    return (unf(treedef, [o[0] for o in out]),
            unf(treedef, [o[1] for o in out]))
