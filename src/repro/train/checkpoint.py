"""Sharded checkpointing with atomic commits, async writes and ELASTIC
restore (any saved topology -> any new mesh/sharding).

Layout per step:  <dir>/step_0000123/
    manifest.json      tree structure, shapes, dtypes, step, data-state
    arrays.npz         flattened leaves (this container is single-host; on
                       a real pod each host writes arrays_<host>.npz with
                       its addressable shards — the manifest format already
                       carries the global shapes needed to reassemble)

Commit protocol: write into ``<dir>/tmp_<step>``, fsync, then atomic
``rename`` to ``step_<n>`` — a preempted writer never leaves a readable
half-checkpoint.  ``keep`` bounds retained checkpoints.

Elastic restore: leaves are loaded as host arrays and ``jax.device_put``
with the NEW shardings — resharding from a 16x16 run to a 2x16x16 run (or
a differently-sharded single-host debug run) is the same code path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

Tree = Any

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _flatten_with_paths(tree: Tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


def save(ckpt_dir: str | Path, step: int, state: Tree,
         extra: Optional[Dict] = None, *, keep: int = 3) -> Path:
    """Synchronous atomic checkpoint write."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"tmp_{step:07d}"
    final = ckpt_dir / f"step_{step:07d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    keys, leaves, _ = _flatten_with_paths(state)
    host_leaves = jax.device_get(leaves)
    arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(host_leaves)}
    np.savez(tmp / _ARRAYS, **arrays)
    manifest = {
        "step": step,
        "keys": keys,
        "shapes": [list(np.asarray(l).shape) for l in host_leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in host_leaves],
        "extra": extra or {},
    }
    (tmp / _MANIFEST).write_text(json.dumps(manifest))
    with open(tmp / _MANIFEST) as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                       # atomic commit
    _gc(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """Background-thread writer: the device->host copy happens on the
    caller, serialization/IO overlaps the next train steps."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._last: Optional[Future] = None

    def save(self, step: int, state: Tree,
             extra: Optional[Dict] = None) -> Future:
        self.wait()
        host_state = jax.device_get(state)   # snapshot before mutation
        self._last = self._pool.submit(save, self.ckpt_dir, step,
                                       host_state, extra, keep=self.keep)
        return self._last

    def wait(self):
        if self._last is not None:
            self._last.result()
            self._last = None


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, template: Tree, *, step: Optional[int] = None,
            shardings: Optional[Tree] = None):
    """Restore into the structure of ``template``; ``shardings`` (a tree of
    Sharding or None) performs the elastic reshard on load."""
    ckpt_dir = Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:07d}"
    manifest = json.loads((d / _MANIFEST).read_text())
    data = np.load(d / _ARRAYS)

    keys, leaves, treedef = _flatten_with_paths(template)
    assert keys == manifest["keys"], (
        "checkpoint tree mismatch:\n saved=%s\n want=%s"
        % (manifest["keys"][:5], keys[:5]))
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None) if shardings is not None
        else [None] * len(leaves))
    out = []
    for i, (leaf, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = data[f"a{i}"]
        want = tuple(getattr(leaf, "shape", arr.shape))
        assert tuple(arr.shape) == want, (keys[i], arr.shape, want)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(ckpt_dir.glob("step_*"),
                   key=lambda p: int(p.name.split("_")[1]))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
