"""Fault-tolerant training loop.

Scale features (all exercised by tests on CPU):
  * checkpoint/restart — async atomic checkpoints every ``ckpt_every``
    steps; ``Trainer.fit`` resumes from the latest checkpoint (params,
    optimizer, data-stream position) after any crash/preemption.
  * NaN/Inf rollback — a non-finite loss triggers restore of the last good
    checkpoint and a DATA SKIP past the poisoned batch window (the
    standard large-run "loss-spike" recovery).
  * preemption — SIGTERM/SIGINT set a flag; the loop checkpoints and exits
    cleanly at the next step boundary.
  * straggler mitigation — per-step deadline monitor (EMA x factor);
    deadline misses invoke a pluggable callback (on a real pod: re-slice
    the job / evict the slow host; here: counted + logged).
  * elastic restart — restore() reshards to whatever mesh/shardings the
    new incarnation uses (see checkpoint.py).
"""
from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.data.synthetic import SyntheticTokenStream
from . import checkpoint as ckpt

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    nan_rollback: bool = True
    max_rollbacks: int = 3
    skip_on_rollback: int = 1       # batches to skip past a loss spike
    straggler_factor: float = 3.0   # deadline = factor x EMA(step time)
    straggler_warmup: int = 10


class Trainer:
    def __init__(self, step_fn: Callable, data: SyntheticTokenStream,
                 cfg: TrainerConfig,
                 straggler_cb: Optional[Callable[[int, float], None]] = None,
                 shardings: Optional[Any] = None):
        self.step_fn = step_fn
        self.data = data
        self.cfg = cfg
        self.shardings = shardings
        self.straggler_cb = straggler_cb or (lambda step, t: None)
        self.saver = ckpt.AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.preempted = False
        self.rollbacks = 0
        self.straggler_events = 0
        self.metrics_history: list = []

    # -- preemption -------------------------------------------------------
    def install_signal_handlers(self):
        def handler(signum, frame):
            log.warning("preemption signal %s — checkpoint at next step",
                        signum)
            self.preempted = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    # -- main loop ----------------------------------------------------------
    def fit(self, state, *, resume: bool = True):
        cfg = self.cfg
        start_step = 0
        if resume and ckpt.latest_step(cfg.ckpt_dir) is not None:
            state, extra = ckpt.restore(cfg.ckpt_dir, state,
                                        shardings=self.shardings)
            start_step = int(extra["train_step"])
            self.data.step = int(extra["data_step"])
            log.info("resumed at step %d", start_step)

        ema = None
        step = start_step
        while step < cfg.total_steps and not self.preempted:
            t0 = time.perf_counter()
            batch = self.data.next_batch()
            new_state, metrics = self.step_fn(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.perf_counter() - t0

            if not np.isfinite(loss) and cfg.nan_rollback:
                state, step = self._rollback(state, step)
                continue

            state = new_state
            step += 1
            self.metrics_history.append({"step": step, "loss": loss,
                                         "time_s": dt})
            if step % cfg.log_every == 0 or step == cfg.total_steps:
                log.info("step %d loss %.4f (%.0f ms)", step, loss, dt * 1e3)

            # straggler deadline (EMA starts at the SECOND step: the first
            # carries jit compilation and would poison the baseline)
            if step - start_step >= 2:
                if ema is None:
                    ema = dt
                elif step - start_step > cfg.straggler_warmup and \
                        dt > cfg.straggler_factor * ema:
                    self.straggler_events += 1
                    self.straggler_cb(step, dt)
                ema = 0.9 * ema + 0.1 * dt

            if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
                self._save(step, state)

        if self.preempted:
            self._save(step, state)
            self.saver.wait()
        self.saver.wait()
        return state, step

    # -- internals ----------------------------------------------------------
    def _save(self, step, state):
        self.saver.save(step, state, extra={
            "train_step": step, "data_step": self.data.step})

    def _rollback(self, state, step):
        self.rollbacks += 1
        if self.rollbacks > self.cfg.max_rollbacks:
            raise RuntimeError("too many NaN rollbacks — aborting")
        # Flush any in-flight async save BEFORE probing the directory: the
        # last good checkpoint may still be in the writer thread.
        self.saver.wait()
        last = ckpt.latest_step(self.cfg.ckpt_dir)
        if last is None:
            raise RuntimeError("non-finite loss before first checkpoint")
        state, extra = ckpt.restore(self.cfg.ckpt_dir, state,
                                    shardings=self.shardings)
        restored = int(extra["train_step"])
        # Skip past the poisoned data window.
        self.data.step = int(extra["data_step"]) + self.cfg.skip_on_rollback \
            + (step - restored)
        log.warning("non-finite loss at step %d -> rolled back to %d, "
                    "data skipped to %d", step, restored, self.data.step)
        return state, restored
