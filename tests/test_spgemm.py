import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CSR, SpgemmConfig, compression_ratio, random_csr,
                        spgemm)


def _pair(seed, m=48, k=40, n=56, da=4.0, db=3.0, dist="uniform"):
    A = random_csr(jax.random.PRNGKey(seed), m, k, avg_nnz_per_row=da,
                   distribution=dist)
    B = random_csr(jax.random.PRNGKey(seed + 1), k, n, avg_nnz_per_row=db,
                   distribution=dist)
    return A, B


@pytest.mark.parametrize("method", ["esc", "hash"])
@pytest.mark.parametrize("dist", ["uniform", "powerlaw", "banded"])
def test_spgemm_matches_dense(method, dist):
    A, B = _pair(7, dist=dist)
    res = spgemm(A, B, SpgemmConfig(method=method))
    ref = np.asarray(A.to_dense()) @ np.asarray(B.to_dense())
    np.testing.assert_allclose(np.asarray(res.C.to_dense()), ref,
                               rtol=1e-5, atol=1e-5)


def test_two_phase_nnz_exact():
    A, B = _pair(11)
    res = spgemm(A, B)
    dense = np.asarray(A.to_dense()) @ np.asarray(B.to_dense())
    a = np.asarray(A.to_dense()) != 0
    b = np.asarray(B.to_dense()) != 0
    support = (a.astype(np.int64) @ b.astype(np.int64)) > 0
    assert res.total_nnz == support.sum()
    rpt = np.asarray(res.C.rpt)
    np.testing.assert_array_equal(rpt[1:] - rpt[:-1], support.sum(axis=1))


def test_output_rows_sorted_by_column():
    A, B = _pair(13, dist="powerlaw")
    res = spgemm(A, B)
    rpt, col = np.asarray(res.C.rpt), np.asarray(res.C.col)
    for i in range(A.nrows):
        seg = col[rpt[i]:rpt[i + 1]]
        assert (np.diff(seg) > 0).all()


def test_fused_esc_equals_two_phase():
    A, B = _pair(17)
    r1 = spgemm(A, B, SpgemmConfig(method="esc"))
    r2 = spgemm(A, B, SpgemmConfig(method="esc", fuse_esc=True))
    np.testing.assert_allclose(np.asarray(r1.C.to_dense()),
                               np.asarray(r2.C.to_dense()), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(r1.C.rpt), np.asarray(r2.C.rpt))


def test_hash_equals_esc():
    A, B = _pair(19, dist="powerlaw")
    r1 = spgemm(A, B, SpgemmConfig(method="esc"))
    r2 = spgemm(A, B, SpgemmConfig(method="hash"))
    np.testing.assert_array_equal(np.asarray(r1.C.rpt), np.asarray(r2.C.rpt))
    np.testing.assert_allclose(np.asarray(r1.C.to_dense()),
                               np.asarray(r2.C.to_dense()), rtol=1e-5,
                               atol=1e-6)


def test_matrix_square():
    """The paper's benchmark is A^2 — exercise the square path."""
    A = random_csr(jax.random.PRNGKey(3), 60, 60, avg_nnz_per_row=4.0)
    res = spgemm(A, A)
    ref = np.asarray(A.to_dense())
    np.testing.assert_allclose(np.asarray(res.C.to_dense()), ref @ ref,
                               rtol=1e-5, atol=1e-5)
    cr = compression_ratio(A, A, res.C)
    assert cr >= 1.0


def test_empty_result():
    # A's columns only hit empty rows of B.
    a = np.zeros((4, 4), np.float32)
    a[0, 3] = 1.0
    b = np.zeros((4, 4), np.float32)
    b[0, 0] = 1.0  # row 3 of B is empty
    A, B = CSR.from_dense(a), CSR.from_dense(b)
    res = spgemm(A, B)
    assert res.total_nnz == 0
    np.testing.assert_allclose(np.asarray(res.C.to_dense()), a @ b)


def test_duplicate_accumulation_correctness():
    """Rows of A with repeated columns hitting the same B row must sum."""
    a = np.array([[2.0, 3.0], [1.0, 0.0]], np.float32)
    b = np.array([[1.0, 4.0], [1.0, 4.0]], np.float32)
    A, B = CSR.from_dense(a), CSR.from_dense(b)
    res = spgemm(A, B)
    np.testing.assert_allclose(np.asarray(res.C.to_dense()), a @ b)
    assert res.total_nprod == 3 * 2  # 3 A entries x 2-entry B rows
    assert res.total_nnz == 4
    assert res.compression_ratio == pytest.approx(1.5)


def test_timing_instrumentation():
    A, B = _pair(23)
    res = spgemm(A, B, SpgemmConfig(timing=True))
    for step in ("setup", "symbolic_binning", "symbolic", "alloc",
                 "numeric_binning", "numeric"):
        assert step in res.timings


def test_rectangular_shapes():
    A, B = _pair(29, m=10, k=64, n=7, da=6.0, db=2.0)
    res = spgemm(A, B)
    ref = np.asarray(A.to_dense()) @ np.asarray(B.to_dense())
    np.testing.assert_allclose(np.asarray(res.C.to_dense()), ref, rtol=1e-5,
                               atol=1e-5)
