"""Estimation-based cold planning (``plan_mode="estimate"``).

Covers the host-side sampling estimator (exact n_prod, column-union
sample measurement, band-derived rung counts), its engine integration
(cold calls specialize straight from the estimate; overflow-grow is the
correctness net), int-width safety near 2^31, and dump v4 persistence
of the new plan fields.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.core import (CSR, SpgemmConfig, bin_rows_for_ladder, esc,
                        next_bucket, random_csr, spgemm_reference)
from repro.core.analysis import (derive_estimate, estimate_result,
                                 host_index, host_nprod, measure_sample_nnz,
                                 nprod_into_rpt, sample_rows_for_estimate)
from repro.engine import MatrixSig, SpgemmEngine, total_traces
from repro.engine import executor as executor_mod


def _pair(seed, m=48, k=40, n=44, da=3.0, db=3.0, dist="uniform"):
    A = random_csr(jax.random.PRNGKey(seed), m, k, avg_nnz_per_row=da,
                   distribution=dist)
    B = random_csr(jax.random.PRNGKey(seed + 1), k, n, avg_nnz_per_row=db,
                   distribution=dist)
    return A, B


def _true_nnz_per_row(A, B):
    """Oracle: exact structural nnz per C row via the esc symbolic pass."""
    nprod = np.asarray(jax.device_get(nprod_into_rpt(A, B)[:A.nrows]))
    buf = esc.symbolic(A, B,
                       prod_capacity=next_bucket(max(int(nprod.sum()), 1)))
    return np.asarray(jax.device_get(buf[:A.nrows]), dtype=np.int64)


# ---------------------------------------------------------------------------
# Host-side measurement primitives.
# ---------------------------------------------------------------------------

def test_host_nprod_matches_device():
    A, B = _pair(11)
    a_rpt, a_col = host_index(A)
    b_rpt, _ = host_index(B)
    host = host_nprod(a_rpt, a_col, b_rpt)
    dev = np.asarray(jax.device_get(nprod_into_rpt(A, B)[:A.nrows]))
    np.testing.assert_array_equal(host, dev)


def test_measure_sample_nnz_is_exact():
    A, B = _pair(13, dist="powerlaw", da=4.0)
    a_rpt, a_col = host_index(A)
    b_rpt, b_col = host_index(B)
    true_nnz = _true_nnz_per_row(A, B)
    rows = np.arange(A.nrows, dtype=np.int64)      # "sample" = every row
    measured = measure_sample_nnz(rows, a_rpt, a_col, b_rpt, b_col)
    np.testing.assert_array_equal(measured, true_nnz)


def test_sample_rows_deterministic_and_stratified():
    nprod = np.array([0, 9, 1, 7, 0, 3, 100, 2, 5, 4], dtype=np.int64)
    rows = sample_rows_for_estimate(nprod, n_sample=4)
    assert rows.size == 4
    assert 6 in rows                     # the heaviest row is always taken
    assert np.all(nprod[rows] > 0)       # empty rows carry no ratio signal
    np.testing.assert_array_equal(
        rows, sample_rows_for_estimate(nprod, n_sample=4))
    # Small populations come back whole.
    np.testing.assert_array_equal(
        sample_rows_for_estimate(nprod, n_sample=64), np.flatnonzero(nprod))


# ---------------------------------------------------------------------------
# Estimator accuracy across row-size distributions.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["uniform", "powerlaw", "banded"])
def test_estimate_bounds_true_sizes(dist):
    A, B = _pair(17, m=96, k=80, n=72, da=4.0, dist=dist)
    cfg = SpgemmConfig()
    sym_lad, num_lad = cfg.ladders()
    est = estimate_result(A, B, sym_upper=sym_lad.upper,
                          num_upper=num_lad.upper)
    nprod = np.asarray(jax.device_get(nprod_into_rpt(A, B)[:A.nrows]),
                       dtype=np.int64)
    true_nnz = _true_nnz_per_row(A, B)

    # Symbolic side is EXACT: n_prod is held exactly, so the rung counts
    # must equal the device binning's.
    assert est.total_nprod == int(nprod.sum())
    sym_bn = bin_rows_for_ladder(jax.numpy.asarray(nprod.astype(np.int32)),
                                 sym_lad)
    dev_counts = np.asarray(jax.device_get(sym_bn.bin_size),
                            dtype=np.int64)
    np.testing.assert_array_equal(np.asarray(est.sym_counts), dev_counts)

    # Numeric side is a band: the total must cover the truth without
    # blowing past the trivial nprod bound, and each true rung count must
    # be covered by the range-histogram's per-rung upper bound.
    assert 0.0 <= est.r_lo <= est.r_hi <= 1.0
    assert int(true_nnz.sum()) <= est.total_nnz_high <= est.total_nprod
    num_bn = bin_rows_for_ladder(
        jax.numpy.asarray(true_nnz.astype(np.int32)), num_lad)
    true_counts = np.asarray(jax.device_get(num_bn.bin_size),
                             dtype=np.int64)
    assert np.all(true_counts <= np.asarray(est.num_counts))


def test_estimate_all_empty_rows():
    m, k, n = 16, 12, 10
    A = CSR.from_dense(np.zeros((m, k), dtype=np.float32))
    B = CSR.from_dense(np.zeros((k, n), dtype=np.float32))
    cfg = SpgemmConfig()
    sym_lad, num_lad = cfg.ladders()
    est = estimate_result(A, B, sym_upper=sym_lad.upper,
                          num_upper=num_lad.upper)
    assert est.sampled_rows == 0
    assert est.total_nprod == 0 and est.total_nnz_high == 0
    assert est.sym_fall_prod == 0 and est.num_fall_prod == 0
    # Empty rows land on rung 0 — exactly where the device binning puts
    # zero-size rows, so the admits checks stay consistent.
    assert est.sym_counts[0] == m and sum(est.sym_counts) == m
    assert est.num_counts[0] == m


def test_derive_estimate_near_2p31_is_int64_safe():
    # Four rows whose products sum past 2^32: any int32 intermediate
    # would wrap negative and poison the capacity buckets.
    big = np.int64(2**30)
    nprod = np.full(4, big, dtype=np.int64)
    est = derive_estimate(
        nprod, np.array([0], dtype=np.int64), np.array([big]),
        sym_upper=(16, 512), num_upper=(16, 512), ncols=2**31 - 1)
    assert est.total_nprod == 4 * int(big) > 2**31
    assert est.total_nnz_high == 4 * int(big)       # r_hi == 1 band
    assert est.sym_fall_prod == 4 * int(big)        # all rows on fallback
    assert est.num_fall_prod == 4 * int(big)
    assert all(c >= 0 for c in est.sym_counts + est.num_counts)


# ---------------------------------------------------------------------------
# Engine integration: estimate-mode cold path.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method,fused,packed", [
    ("esc", False, False),
    ("hash", False, False),
    ("hash", True, True),
])
def test_estimate_cold_path_skips_symbolic_sizing(method, fused, packed):
    A, B = _pair(23)
    cfg = SpgemmConfig(method=method, fuse_numeric=fused, row_packing=packed,
                       plan_mode="estimate")
    engine = SpgemmEngine(cfg)
    res = engine.execute(A, B)
    np.testing.assert_allclose(np.asarray(res.C.to_dense()),
                               np.asarray(spgemm_reference(A, B)),
                               rtol=1e-4, atol=1e-4)
    # The full symbolic sizing pass never ran: zero steps calls, one
    # estimated plan, confirmed by the admitted finalize.
    assert sum(e.stats.steps_calls for _, e in engine.cache.items()) == 0
    assert engine.stats.estimates == 1
    assert engine.stats.estimate_hits == 1
    assert engine.stats.estimate_misses == 0
    entry = engine.cache.get((MatrixSig.of(A), MatrixSig.of(B), cfg))
    assert entry.plan.is_specialized
    assert not entry.plan.policy.estimated     # cleared on confirm
    # Cold timings carry the estimate-phase breakdown for benchmarks.
    assert "estimate" in res.timings and "compile_dispatch" in res.timings
    # Steady state: the repeat request is served hot with no new trace.
    before = total_traces()
    res2 = engine.execute(A, B)
    assert total_traces() == before
    assert np.array_equal(np.asarray(res2.C.rpt), np.asarray(res.C.rpt))


@pytest.mark.parametrize("method", ["esc", "hash"])
def test_deliberate_under_estimate_recovers_bitwise(method, monkeypatch):
    """A lowballed estimate must be caught by the overflow verify and
    corrected by the grow-and-redo steps oracle — bitwise identical to
    the exact-mode result, with the miss recorded for calibration."""
    A, B = _pair(29, da=4.0, db=4.0)
    exact = SpgemmEngine(SpgemmConfig(method=method)).execute(A, B)

    real = estimate_result

    def lowball(A, B, **kw):
        est = real(A, B, **kw)
        return dataclasses.replace(
            est, total_nnz_high=1, num_fall_prod=0,
            num_counts=(0,) * len(est.num_counts))

    monkeypatch.setattr(executor_mod, "estimate_result", lowball)
    cfg = SpgemmConfig(method=method, plan_mode="estimate")
    engine = SpgemmEngine(cfg)
    headroom0 = engine.est_state.headroom
    res = engine.execute(A, B)

    assert engine.stats.estimates == 1
    assert engine.stats.estimate_misses == 1
    assert engine.est_state.headroom > headroom0   # calibration learned
    nnz = exact.total_nnz
    assert res.total_nnz == nnz
    assert np.array_equal(np.asarray(res.C.rpt), np.asarray(exact.C.rpt))
    assert np.array_equal(np.asarray(res.C.col)[:nnz],
                          np.asarray(exact.C.col)[:nnz])
    assert np.array_equal(np.asarray(res.C.val)[:nnz],
                          np.asarray(exact.C.val)[:nnz])
    # The corrected plan serves the next request without another miss.
    engine.execute(A, B)
    assert engine.stats.estimate_misses == 1


def test_estimator_prewarm_specializes_without_execution():
    A, B = _pair(31)
    cfg = SpgemmConfig(method="hash", plan_mode="estimate")
    engine = SpgemmEngine(cfg)
    p = engine.prewarm(A, B)
    assert p.is_specialized
    assert p.hash_schedule is not None       # buckets alone can't do this
    assert p.policy.estimated                # unverified until a finalize
    assert engine.stats.estimates == 1
    res = engine.execute(A, B)
    np.testing.assert_allclose(np.asarray(res.C.to_dense()),
                               np.asarray(spgemm_reference(A, B)),
                               rtol=1e-4, atol=1e-4)
    assert sum(e.stats.steps_calls for _, e in engine.cache.items()) == 0
    assert engine.stats.estimate_hits == 1


def test_prewarm_rejects_half_specified_buckets():
    A, B = _pair(37)
    engine = SpgemmEngine()
    with pytest.raises(ValueError):
        engine.prewarm(A, B, prod_bucket=256)


def test_exact_mode_never_estimates():
    A, B = _pair(41)
    engine = SpgemmEngine(SpgemmConfig(method="esc"))
    engine.execute(A, B)
    engine.execute(A, B)
    assert engine.stats.estimates == 0


def test_invalid_plan_mode_rejected():
    A, B = _pair(43)
    engine = SpgemmEngine()
    with pytest.raises(ValueError):
        engine.execute(A, B, SpgemmConfig(plan_mode="guess"))


# ---------------------------------------------------------------------------
# Dump v4 persistence of the estimate-mode plan fields.
# ---------------------------------------------------------------------------

def test_dump_v4_roundtrips_plan_mode_and_estimated(tmp_path):
    A, B = _pair(47)
    cfg = SpgemmConfig(method="hash", plan_mode="estimate")
    engine = SpgemmEngine(cfg)
    engine.prewarm(A, B)            # estimated=True persists (no finalize)
    path = str(tmp_path / "plans.json")
    engine.cache.dump(path)

    blob = json.load(open(path))
    assert blob["version"] == 4
    assert blob["plans"][0]["config"]["plan_mode"] == "estimate"
    assert blob["plans"][0]["policy"]["estimated"] is True

    fresh = SpgemmEngine(cfg)
    fresh.cache.load(path)
    entry = fresh.cache.get((MatrixSig.of(A), MatrixSig.of(B), cfg))
    assert entry.plan.config.plan_mode == "estimate"
    assert entry.plan.policy.estimated
    res = fresh.execute(A, B)       # straight to hot; finalize verifies
    np.testing.assert_allclose(np.asarray(res.C.to_dense()),
                               np.asarray(spgemm_reference(A, B)),
                               rtol=1e-4, atol=1e-4)
    assert sum(e.stats.steps_calls for _, e in fresh.cache.items()) == 0


def test_v3_dump_loads_with_default_plan_fields(tmp_path):
    A, B = _pair(53)
    cfg = SpgemmConfig(method="hash")
    warm = SpgemmEngine(cfg)
    warm.execute(A, B)
    warm.execute(A, B)
    path = str(tmp_path / "plans.json")
    warm.cache.dump(path)

    blob = json.load(open(path))
    blob["version"] = 3             # pre-estimate payload: no new fields
    for p in blob["plans"]:
        p["config"].pop("plan_mode")
        if p.get("policy"):
            p["policy"].pop("estimated")
    json.dump(blob, open(path, "w"))

    fresh = SpgemmEngine(cfg)
    assert fresh.cache.load(path) >= 1
    entry = fresh.cache.get((MatrixSig.of(A), MatrixSig.of(B), cfg))
    assert entry.plan.config.plan_mode == "exact"    # dataclass default
    assert entry.plan.policy.estimated is False
