"""Partition-aware engine tests: balance, sharded parity, per-shard
growth, completion-order drain, and plan-cache persistence."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import (CSR, SpgemmConfig, next_bucket, random_csr, spgemm,
                        spgemm_reference)
from repro.core.analysis import row_flops
from repro.engine import (MatrixSig, PlanCache, ShardSpec, SpgemmEngine,
                          balanced_bounds, plan_shards, shard_devices,
                          total_traces)
from repro.launch.mesh import data_axis_devices, make_host_mesh


def _pair(seed, m=32, k=28, n=36, da=3.0, db=3.0, dist="uniform"):
    A = random_csr(jax.random.PRNGKey(seed), m, k, avg_nnz_per_row=da,
                   distribution=dist)
    B = random_csr(jax.random.PRNGKey(seed + 1), k, n, avg_nnz_per_row=db,
                   distribution=dist)
    return A, B


# ---------------------------------------------------------------------------
# The partitioner: flop-balanced contiguous row blocks.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [2, 3, 4])
def test_balanced_bounds_skewed_weights(n_shards):
    # Skewed: a heavy head (100x the tail) — an even ROW split would give
    # shard 0 nearly all the flops; the flop split must stay within 2x of
    # the mean.
    weights = np.concatenate([np.full(8, 100, np.int64),
                              np.full(56, 1, np.int64)])
    bounds = balanced_bounds(weights, n_shards)
    assert bounds[0] == 0 and bounds[-1] == len(weights)
    assert list(bounds) == sorted(bounds)
    loads = [int(weights[bounds[s]:bounds[s + 1]].sum())
             for s in range(n_shards)]
    mean = weights.sum() / n_shards
    assert max(loads) <= 2 * mean, (loads, mean)


def test_balanced_bounds_on_flop_estimate():
    # End-to-end with the real flop estimate on a powerlaw matrix.
    A, B = _pair(11, m=128, da=4.0, dist="powerlaw")
    flops = row_flops(A, B)
    assert flops.dtype == np.int64        # host-side, wrap-proof weights
    bounds = balanced_bounds(flops, 4)
    loads = [int(flops[bounds[s]:bounds[s + 1]].sum()) for s in range(4)]
    assert max(loads) <= 2 * (flops.sum() / 4), (loads, flops.sum())


def test_balanced_bounds_degenerate_inputs():
    assert balanced_bounds(np.zeros(6, np.int64), 3) == (0, 2, 4, 6)
    assert balanced_bounds(np.ones(2, np.int64), 5) == (0, 1, 2)  # clamped
    assert balanced_bounds(np.ones(0, np.int64), 3) == (0, 0)


def test_plan_shards_buckets_are_pow2():
    A, B = _pair(13, m=50, da=3.0)
    spec = plan_shards(np.asarray(jax.device_get(A.rpt)),
                       row_flops(A, B), 3)
    assert spec.n_shards == 3
    assert sum(spec.rows(s) for s in range(3)) == A.nrows
    for s in range(3):
        rb, cb = spec.row_buckets[s], spec.cap_buckets[s]
        assert rb >= spec.rows(s) and rb & (rb - 1) == 0
        assert cb & (cb - 1) == 0
    # Per-shard growth touches only the grown shard's bucket.
    grown = spec.with_cap_bucket(1, spec.cap_buckets[1] + 1)
    assert grown.cap_buckets[1] > spec.cap_buckets[1]
    assert grown.cap_buckets[0] == spec.cap_buckets[0]
    assert grown.cap_buckets[2] == spec.cap_buckets[2]
    assert grown.bounds == spec.bounds


# ---------------------------------------------------------------------------
# CSR.row_slice: the shard substrate.
# ---------------------------------------------------------------------------

def test_row_slice_roundtrip_and_padding():
    A, _ = _pair(17, m=24)
    dense = np.asarray(A.to_dense())
    sl = A.row_slice(3, 17)
    np.testing.assert_array_equal(np.asarray(sl.to_dense()), dense[3:17])
    # Padded to static buckets: extra rows are empty, storage zero-filled.
    padded = A.row_slice(3, 17, nrows=32, capacity=256)
    assert padded.shape == (32, A.ncols) and padded.capacity == 256
    out = np.asarray(padded.to_dense())
    np.testing.assert_array_equal(out[:14], dense[3:17])
    assert not out[14:].any()
    # Whole-matrix slice is the identity in structure.
    whole = A.row_slice(0, A.nrows)
    np.testing.assert_array_equal(np.asarray(whole.to_dense()), dense)


# ---------------------------------------------------------------------------
# Sharded execution: parity with the unsharded path and the oracle.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["esc", "hash"])
def test_sharded_matches_unsharded_bitwise(method):
    A, B = _pair(23, m=48, dist="powerlaw")
    ref = np.asarray(spgemm_reference(A, B))
    base = SpgemmEngine(SpgemmConfig(method=method)).execute(A, B)
    engine = SpgemmEngine(SpgemmConfig(method=method), shards=3)
    for r in (engine.execute(A, B),       # cold (learns the partition)
              engine.execute(A, B)):      # hot (per-shard executables)
        np.testing.assert_allclose(np.asarray(r.C.to_dense()), ref,
                                   rtol=1e-5, atol=1e-5)
        assert r.total_nnz == base.total_nnz
        assert r.total_nprod == base.total_nprod
        np.testing.assert_array_equal(np.asarray(r.C.rpt),
                                      np.asarray(base.C.rpt))
        nnz = base.total_nnz
        np.testing.assert_array_equal(np.asarray(r.C.col)[:nnz],
                                      np.asarray(base.C.col)[:nnz])
        np.testing.assert_allclose(np.asarray(r.C.val)[:nnz],
                                   np.asarray(base.C.val)[:nnz])
    parent = engine.cache.get(
        (MatrixSig.of(A), MatrixSig.of(B),
         SpgemmConfig(method=method, shards=3)))
    assert parent is not None and parent.plan.shard_spec is not None


def test_spgemm_shards_knob_routes_through_engine():
    A, B = _pair(29)
    ref = np.asarray(spgemm_reference(A, B))
    r = spgemm(A, B, shards=2)
    np.testing.assert_allclose(np.asarray(r.C.to_dense()), ref,
                               rtol=1e-5, atol=1e-5)


def test_sharded_stream_zero_retraces_and_cache_hits():
    engine = SpgemmEngine(shards=2)
    A, B = _pair(31)
    cap_a, cap_b = MatrixSig.of(A).cap_bucket, MatrixSig.of(B).cap_bucket
    engine.execute(A, B)                   # cold: learns partition + buckets
    engine.execute(A, B)                   # first hot call traces shards
    baseline = total_traces()
    for s in range(4):                     # distinct same-bucket matrices
        A2, B2 = _pair(40 + s)
        r = engine.execute(A2.with_capacity(cap_a), B2.with_capacity(cap_b))
        ref = np.asarray(spgemm_reference(A2, B2))
        np.testing.assert_allclose(np.asarray(r.C.to_dense()), ref,
                                   rtol=1e-5, atol=1e-5)
    assert total_traces() == baseline      # zero retraces on repeats
    assert engine.stats.shard_grows == 0
    assert engine.cache.hit_rate >= 0.75   # stream-wide, incl. cold misses


def test_per_shard_bucket_growth_touches_one_shard():
    m = 32
    d_even = np.zeros((m, m), np.float32)
    d_even[:, 0] = 1.0                     # 1 nnz/row, uniform balance
    d_skew = np.zeros((m, m), np.float32)
    d_skew[:, 0] = 1.0
    d_skew[m // 2:, :24] = 1.0             # bottom half outgrows its slice
    dB = np.eye(m, dtype=np.float32)
    A_even = CSR.from_dense(d_even).with_capacity(1024)
    A_skew = CSR.from_dense(d_skew).with_capacity(1024)
    assert MatrixSig.of(A_even) == MatrixSig.of(A_skew)
    Bc = CSR.from_dense(dB)

    engine = SpgemmEngine(shards=2)
    engine.execute(A_even, Bc)             # learns an even partition
    key = (MatrixSig.of(A_even), MatrixSig.of(Bc),
           SpgemmConfig(shards=2))
    spec0 = engine.cache.get(key).plan.shard_spec
    r = engine.execute(A_skew, Bc)         # shard 1's slice overflows
    np.testing.assert_allclose(np.asarray(r.C.to_dense()), d_skew @ dB,
                               rtol=1e-5)
    assert engine.stats.shard_grows >= 1
    spec1 = engine.cache.get(key).plan.shard_spec
    assert spec1.bounds == spec0.bounds            # partition pinned
    assert spec1.cap_buckets[0] == spec0.cap_buckets[0]   # shard 0 untouched
    assert spec1.cap_buckets[1] > spec0.cap_buckets[1]    # shard 1 grown
    r2 = engine.execute(A_skew, Bc)        # grown bucket now admits it
    np.testing.assert_allclose(np.asarray(r2.C.to_dense()), d_skew @ dB,
                               rtol=1e-5)


def test_sharded_on_two_device_mesh_subprocess():
    """Shard results land committed to different devices; the merge must
    gather them home instead of crashing (regression: 'incompatible
    devices for jitted computation').  Needs the device-count XLA flag
    set before jax initializes, hence the subprocess."""
    script = """
import jax, numpy as np
assert len(jax.devices()) == 2
from repro.core import random_csr, spgemm_reference
from repro.engine import SpgemmEngine
from repro.launch.mesh import make_host_mesh
A = random_csr(jax.random.PRNGKey(0), 40, 36, avg_nnz_per_row=3.0)
B = random_csr(jax.random.PRNGKey(1), 36, 30, avg_nnz_per_row=3.0)
eng = SpgemmEngine(shards=2, mesh=make_host_mesh())
for _ in range(2):   # cold + hot
    r = eng.execute(A, B)
    np.testing.assert_allclose(np.asarray(r.C.to_dense()),
                               np.asarray(spgemm_reference(A, B)),
                               rtol=1e-5, atol=1e-5)
"""
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=src)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]


def test_sharded_with_mesh_placement():
    mesh = make_host_mesh()
    assert len(data_axis_devices(mesh)) >= 1
    assert len(shard_devices(mesh, 3)) == 3
    engine = SpgemmEngine(shards=2, mesh=mesh)
    A, B = _pair(53)
    r = engine.execute(A, B)
    np.testing.assert_allclose(np.asarray(r.C.to_dense()),
                               np.asarray(spgemm_reference(A, B)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Completion-order drain.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("drain_ordered", [False, True])
def test_drain_modes_match_oracle(drain_ordered):
    engine = SpgemmEngine()
    reqs = []
    for s in range(6):
        A, B = _pair(60 + s, m=24 if s % 2 else 40)   # mixed-size stream
        reqs.append((engine.submit(A, B), A, B))
    results = engine.drain(drain_ordered=drain_ordered)
    assert len(results) == len(reqs)
    for uid, A, B in reqs:
        np.testing.assert_allclose(np.asarray(results[uid].C.to_dense()),
                                   np.asarray(spgemm_reference(A, B)),
                                   rtol=1e-5, atol=1e-5)


def test_sharded_drain_matches_oracle():
    engine = SpgemmEngine(shards=2)
    reqs = []
    for s in range(4):
        A, B = _pair(70 + s)
        reqs.append((engine.submit(A, B), A, B))
    results = engine.drain()
    for uid, A, B in reqs:
        np.testing.assert_allclose(np.asarray(results[uid].C.to_dense()),
                                   np.asarray(spgemm_reference(A, B)),
                                   rtol=1e-5, atol=1e-5)
    assert engine.stats.sharded_requests == 4


# ---------------------------------------------------------------------------
# Plan-cache persistence.
# ---------------------------------------------------------------------------

def test_plan_cache_dump_load_roundtrip(tmp_path):
    engine = SpgemmEngine()
    A, B = _pair(81)
    engine.execute(A, B)                                   # ESC plan
    engine.execute(A, B, SpgemmConfig(method="hash"))      # hash schedule
    engine.execute(A, B, SpgemmConfig(shards=2))           # shard spec
    path = str(tmp_path / "plans.json")
    n = engine.cache.dump(path)
    assert n == len(engine.cache)

    blob = json.load(open(path))
    assert blob["version"] == 4 and len(blob["plans"]) == n

    fresh = PlanCache()
    assert fresh.load(path) == n
    orig = {k: e.plan for k, e in engine.cache.items()}
    for key, entry in fresh.items():
        assert entry.plan == orig[key]
        assert entry.executable is None    # executables are not persisted


def test_loaded_cache_prewarms_fresh_engine(tmp_path):
    A, B = _pair(91)
    ref = np.asarray(spgemm_reference(A, B))
    path = str(tmp_path / "plans.json")
    warm = SpgemmEngine(SpgemmConfig(method="hash"), shards=2)
    warm.execute(A, B)
    warm.cache.dump(path)

    engine = SpgemmEngine(SpgemmConfig(method="hash"), shards=2)
    engine.cache.load(path)
    r = engine.execute(A, B)               # straight to the hot path
    np.testing.assert_allclose(np.asarray(r.C.to_dense()), ref,
                               rtol=1e-5, atol=1e-5)
    assert sum(e.stats.steps_calls for _, e in engine.cache.items()) == 0
    assert engine.stats.capacity_grows == 0


def test_sharded_requests_counted_once():
    engine = SpgemmEngine(shards=3)
    A, B = _pair(97)
    engine.execute(A, B)
    engine.execute(A, B)
    assert engine.stats.requests == 2           # not 2 * (1 + n_shards)
    assert engine.stats.sharded_requests == 2


def test_explicit_config_opts_out_of_engine_sharding():
    engine = SpgemmEngine(shards=3)
    A, B = _pair(98)
    r = engine.execute(A, B, SpgemmConfig(shards=1))   # explicit opt-out
    np.testing.assert_allclose(np.asarray(r.C.to_dense()),
                               np.asarray(spgemm_reference(A, B)),
                               rtol=1e-5, atol=1e-5)
    assert engine.stats.sharded_requests == 0


def test_prewarm_rejects_sharded_config():
    engine = SpgemmEngine(shards=2)
    A, B = _pair(96)
    with pytest.raises(ValueError):
        engine.prewarm(A, B, prod_bucket=256, nnz_bucket=256)
    # Explicit unsharded config still prewarms (the sub-problem path).
    p = engine.prewarm(A, B, SpgemmConfig(shards=1),
                       prod_bucket=256, nnz_bucket=256)
    assert p.is_specialized


def test_noop_load_keeps_live_executables(tmp_path):
    engine = SpgemmEngine(shards=2)
    A, B = _pair(99)
    engine.execute(A, B)
    engine.execute(A, B)                       # executables built
    path = str(tmp_path / "plans.json")
    engine.cache.dump(path)
    before = {k: e.executable for k, e in engine.cache.items()}
    assert any(x is not None for x in before.values())
    engine.cache.load(path)                    # merge is a no-op
    for key, entry in engine.cache.items():
        assert entry.executable is before[key]  # zero-retrace state kept


def test_fused_dump_load_roundtrip_through_steady_state(tmp_path):
    """Persistence round-trip for FUSED plans (the default hash config):
    a fresh engine loading the dump serves its first request straight from
    the fused hot path — no cold steps call, no retrace storm — with
    bitwise parity against the warm engine."""
    A, B = _pair(83)
    cfg = SpgemmConfig(method="hash", fuse_numeric=True, row_packing=True)
    warm = SpgemmEngine(cfg)
    base = warm.execute(A, B)
    warm.execute(A, B)                     # fused steady state reached
    path = str(tmp_path / "plans.json")
    warm.cache.dump(path)

    blob = json.load(open(path))
    assert blob["version"] == 4
    assert blob["plans"][0]["policy"] is not None   # state persists

    fresh = SpgemmEngine(cfg)
    fresh.cache.load(path)
    entry = fresh.cache.get((MatrixSig.of(A), MatrixSig.of(B), cfg))
    # Pack alignment survives the round-trip: every populated sym bucket
    # still carves into whole rows_per_block grid steps.
    packs = entry.plan.sym_ladder.rows_per_block
    for b, cap in enumerate(entry.plan.hash_schedule.sym_row_buckets):
        if cap and b < len(packs):
            assert cap % packs[b] == 0
    r = fresh.execute(A, B)                # straight to the fused hot path
    assert sum(e.stats.steps_calls for _, e in fresh.cache.items()) == 0
    assert fresh.stats.capacity_grows == 0
    nnz = base.total_nnz
    assert r.total_nnz == nnz
    np.testing.assert_array_equal(np.asarray(r.C.rpt),
                                  np.asarray(base.C.rpt))
    np.testing.assert_array_equal(np.asarray(r.C.col)[:nnz],
                                  np.asarray(base.C.col)[:nnz])
    np.testing.assert_array_equal(np.asarray(r.C.val)[:nnz],
                                  np.asarray(base.C.val)[:nnz])


def test_load_realigns_stale_unpacked_schedule(tmp_path):
    """A v1 dump (pre-packing/fusion: no policy blob, sym buckets never
    pack-aligned — here a sub-pack, non-pow-2 bucket) must not be taken
    at face value by a fused+packed config: load re-derives the pack
    alignment (monotone) so the fused executable gets whole grid steps,
    and the first request still verifies and matches the oracle."""
    A, B = _pair(87)
    cfg = SpgemmConfig(method="hash", fuse_numeric=True, row_packing=True)
    warm = SpgemmEngine(cfg)
    warm.execute(A, B)
    warm.execute(A, B)
    path = str(tmp_path / "plans.json")
    warm.cache.dump(path)

    blob = json.load(open(path))
    blob["version"] = 1                     # pre-policy payload
    for plan in blob["plans"]:
        del plan["policy"]
        sched = plan["hash_schedule"]
        # De-align: a stale bucket smaller than the rung's pack (and not
        # pow-2) that nevertheless admits the observed sizes.
        sched["sym_row_buckets"] = [
            max(b // 2 + 1, 1) if b else 0
            for b in sched["sym_row_buckets"]]
    json.dump(blob, open(path, "w"))

    fresh = SpgemmEngine(cfg)
    assert fresh.cache.load(path) == len(blob["plans"])
    entry = fresh.cache.get((MatrixSig.of(A), MatrixSig.of(B), cfg))
    packs = entry.plan.sym_ladder.rows_per_block
    for b, cap in enumerate(entry.plan.hash_schedule.sym_row_buckets):
        assert cap == 0 or cap & (cap - 1) == 0          # pow-2 restored
        if cap and b < len(packs):
            assert cap % packs[b] == 0                   # pack-aligned
    r = fresh.execute(A, B)
    np.testing.assert_allclose(np.asarray(r.C.to_dense()),
                               np.asarray(spgemm_reference(A, B)),
                               rtol=1e-5, atol=1e-5)


def test_load_rejects_unknown_version(tmp_path):
    engine = SpgemmEngine()
    A, B = _pair(89)
    engine.execute(A, B)
    path = str(tmp_path / "plans.json")
    engine.cache.dump(path)
    blob = json.load(open(path))
    blob["version"] = 99
    json.dump(blob, open(path, "w"))
    with pytest.raises(ValueError):
        PlanCache().load(path)


def test_shard_spec_union_is_monotone():
    spec = ShardSpec(bounds=(0, 4, 8), row_buckets=(4, 4),
                     cap_buckets=(64, 128))
    bigger = ShardSpec(bounds=(0, 4, 8), row_buckets=(4, 4),
                       cap_buckets=(256, 16))
    assert spec.union(bigger).cap_buckets == (256, 128)
    # Incomparable partitions keep self.
    other = ShardSpec(bounds=(0, 2, 8), row_buckets=(2, 8),
                      cap_buckets=(512, 512))
    assert spec.union(other) is spec


def test_load_merges_monotonically(tmp_path):
    cfg = SpgemmConfig()
    A, B = _pair(95)
    engine = SpgemmEngine()
    engine.prewarm(A, B, prod_bucket=256, nnz_bucket=256)
    path = str(tmp_path / "plans.json")
    engine.cache.dump(path)
    # A cache holding BIGGER buckets must not shrink on load.
    other = SpgemmEngine()
    other.prewarm(A, B, prod_bucket=4096, nnz_bucket=4096)
    other.cache.load(path)
    p = other.cache.get((MatrixSig.of(A), MatrixSig.of(B), cfg)).plan
    assert p.prod_bucket == 4096 and p.nnz_bucket == 4096
