import os

# Keep tests on the single real CPU device (the 512-device override is
# reserved for launch/dryrun.py, per the brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)


@pytest.fixture(autouse=True)
def _reset_trace_counters():
    """Zero the module-global trace counters before every test.

    ``repro.engine.stats`` counts hot-path traces process-wide; without
    this, a trace-count assertion depends on which test files ran first
    (the isolation bug this fixture fixes).  Imported lazily so test
    files that never touch the engine don't pay for it."""
    from repro.engine import stats
    stats.reset()
    yield
