import os

# Keep tests on the single real CPU device (the 512-device override is
# reserved for launch/dryrun.py, per the brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
