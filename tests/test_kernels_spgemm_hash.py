"""Per-kernel sweeps: Pallas hash kernels vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bin_rows_for_ladder, next_bucket, nprod_into_rpt,
                        random_csr, esc)
from repro.core.analysis import exclusive_sum_in_place
from repro.core.binning_ranges import make_ladder, numeric_ladder, symbolic_ladder
from repro.kernels import ref as kref
from repro.kernels import spgemm_hash


def _pair(seed, m, k, n, da, db, dist="uniform", dtype=jnp.float32):
    A = random_csr(jax.random.PRNGKey(seed), m, k, avg_nnz_per_row=da,
                   distribution=dist, dtype=dtype)
    B = random_csr(jax.random.PRNGKey(seed + 100), k, n, avg_nnz_per_row=db,
                   distribution=dist, dtype=dtype)
    return A, B


@pytest.mark.parametrize("shape", [(16, 16, 16, 2.0, 2.0),
                                   (48, 32, 64, 4.0, 3.0),
                                   (9, 130, 7, 8.0, 1.5),
                                   (64, 64, 64, 6.0, 6.0)])
@pytest.mark.parametrize("single_access", [True, False])
def test_symbolic_kernel_sweep(shape, single_access):
    m, k, n, da, db = shape
    A, B = _pair(int(m + n), m, k, n, da, db)
    nprod = nprod_into_rpt(A, B)[:m]
    lad = symbolic_ladder(1.2)
    bn = bin_rows_for_ladder(nprod, lad)
    nnz = spgemm_hash.symbolic_binned(A, B, bn, lad, prod_capacity=1,
                                      single_access=single_access)
    expect = kref.row_nnz_from_support(A, B)
    np.testing.assert_array_equal(np.asarray(nnz[:m]), expect)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("single_access", [True, False])
def test_numeric_kernel_sweep(dtype, single_access):
    if dtype == jnp.float64 and not jax.config.jax_enable_x64:
        dtype = jnp.float32  # x64 disabled: exercise the f32 path twice
    m, k, n = 40, 48, 36
    A, B = _pair(5, m, k, n, 5.0, 4.0, dtype=dtype)
    ref = np.asarray(A.to_dense()) @ np.asarray(B.to_dense())
    nnz_buf = esc.symbolic(A, B, prod_capacity=next_bucket(4096))
    rpt = exclusive_sum_in_place(nnz_buf)
    cap = next_bucket(int(rpt[-1]))
    lad = numeric_ladder(2.0)
    bn = bin_rows_for_ladder(nnz_buf[:m], lad)
    C = spgemm_hash.numeric_binned(A, B, rpt, bn, lad, prod_capacity=1,
                                   nnz_capacity=cap,
                                   single_access=single_access)
    np.testing.assert_allclose(np.asarray(C.to_dense()), ref, rtol=1e-5,
                               atol=1e-5)


def test_tiny_ladder_forces_every_rung():
    """Tiny tables force multi-rung + fallback coverage in one matrix."""
    m = 96
    A, B = _pair(9, m, 200, 150, 10.0, 8.0, dist="powerlaw")
    nprod = nprod_into_rpt(A, B)[:m]
    lad = make_ladder((32, 64, 128), 1.2, (32, 64, 128))
    bn = bin_rows_for_ladder(nprod, lad)
    sizes = np.asarray(bn.bin_size)
    assert (sizes > 0).sum() >= 2, sizes  # at least two rungs exercised
    nnz = spgemm_hash.symbolic_binned(A, B, bn, lad, prod_capacity=1)
    np.testing.assert_array_equal(np.asarray(nnz[:m]),
                                  kref.row_nnz_from_support(A, B))


def test_single_access_reduces_transactions():
    """Fig. 9's mechanism: single-access must strictly reduce table
    transactions whenever any insert happens."""
    m = 64
    A, B = _pair(21, m, 80, 90, 6.0, 5.0)
    nprod = nprod_into_rpt(A, B)[:m]
    lad = symbolic_ladder(1.2)
    bn = bin_rows_for_ladder(nprod, lad)
    _, acc_single = spgemm_hash.symbolic_binned(
        A, B, bn, lad, prod_capacity=1, single_access=True,
        collect_accesses=True)
    _, acc_multi = spgemm_hash.symbolic_binned(
        A, B, bn, lad, prod_capacity=1, single_access=False,
        collect_accesses=True)
    assert int(acc_single) < int(acc_multi)


def test_pow2_and_mod_hash_paths():
    """Symbolic rungs are pow2 (AND-mask), numeric rungs are non-pow2
    (mod) — both must agree with the oracle (paper §5.2 last paragraph)."""
    from repro.kernels.spgemm_hash import _hash_init, _hash_next, _is_pow2
    assert _is_pow2(512) and not _is_pow2(511)
    for t in (512, 511):
        h = _hash_init(jnp.int32(12345), t)
        assert 0 <= int(h) < t
        h2 = _hash_next(jnp.int32(t - 1), t)
        assert int(h2) == 0


def test_scheduled_symbolic_matches_oracle_under_jit():
    """Tentpole regression: the schedule-driven symbolic phase must trace
    cleanly (zero host syncs) and agree with the oracle on a mixed bin
    ladder that populates several rungs AND the ESC fallback rung."""
    m = 96
    A, B = _pair(9, m, 200, 150, 10.0, 8.0, dist="powerlaw")
    nprod = nprod_into_rpt(A, B)[:m]
    lad = make_ladder((32, 64, 128), 1.2, (32, 64, 128))
    bn = bin_rows_for_ladder(nprod, lad)
    row_buckets, fall_cap = spgemm_hash.host_schedule(A, B, bn, lad)
    assert row_buckets[-1] > 0 and fall_cap > 0   # fallback rung exercised

    @jax.jit
    def sym(A, B, bn):
        return spgemm_hash.symbolic_scheduled(
            A, B, bn, lad, row_buckets=row_buckets,
            fallback_prod_capacity=fall_cap)

    nnz_buf, sub_prod, _ = sym(A, B, bn)
    np.testing.assert_array_equal(np.asarray(nnz_buf[:m]),
                                  kref.row_nnz_from_support(A, B))
    assert 0 < int(sub_prod) <= fall_cap


def test_scheduled_pipeline_hash_vs_esc_parity_under_jit():
    """hash-vs-ESC oracle parity with BOTH phases jitted end-to-end on
    tiny mixed ladders (multi-rung + fallback in each phase)."""
    m, k, n = 80, 160, 120
    A, B = _pair(17, m, k, n, 9.0, 7.0, dist="powerlaw")
    sym_lad = make_ladder((32, 64, 128), 1.2, (32, 64, 128))
    num_lad = make_ladder((32, 64, 128), 2.0, (31, 63, 127))

    nprod = nprod_into_rpt(A, B)[:m]
    sym_bn = bin_rows_for_ladder(nprod, sym_lad)
    sym_buckets, sym_fall = spgemm_hash.host_schedule(A, B, sym_bn, sym_lad)
    # Derive the numeric schedule from the (oracle) symbolic result so the
    # jitted pipeline below is schedule-static, like the engine's hot path.
    nnz_oracle = esc.symbolic(A, B, prod_capacity=next_bucket(8192))
    num_bn = bin_rows_for_ladder(nnz_oracle[:m], num_lad)
    num_buckets, num_fall = spgemm_hash.host_schedule(A, B, num_bn, num_lad)
    nnz_cap = next_bucket(int(nnz_oracle.sum()))

    @jax.jit
    def pipeline(A, B):
        nnz_buf, _, _ = spgemm_hash.symbolic_scheduled(
            A, B, bin_rows_for_ladder(nprod_into_rpt(A, B)[:m], sym_lad,
                                      allow_fast_path=False),
            sym_lad, row_buckets=sym_buckets,
            fallback_prod_capacity=sym_fall)
        rpt = exclusive_sum_in_place(nnz_buf)
        num_bn = bin_rows_for_ladder(nnz_buf[:m], num_lad,
                                     allow_fast_path=False)
        C, _, _ = spgemm_hash.numeric_scheduled(
            A, B, rpt, num_bn, num_lad, row_buckets=num_buckets,
            nnz_capacity=nnz_cap, fallback_prod_capacity=num_fall)
        return C

    C = pipeline(A, B)
    esc_C = esc.spgemm_fused(A, B, prod_capacity=next_bucket(8192),
                             nnz_capacity=nnz_cap)
    ref = np.asarray(A.to_dense()) @ np.asarray(B.to_dense())
    np.testing.assert_allclose(np.asarray(C.to_dense()), ref,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(C.to_dense()),
                               np.asarray(esc_C.to_dense()),
                               rtol=1e-5, atol=1e-5)


def test_host_schedule_headroom_and_caps():
    """Learned buckets honor headroom, the pow-2 floor, and the row cap."""
    m = 64
    A, B = _pair(3, m, 64, 64, 4.0, 4.0)
    nprod = nprod_into_rpt(A, B)[:m]
    lad = symbolic_ladder(1.2)
    bn = bin_rows_for_ladder(nprod, lad)
    exact, _ = spgemm_hash.host_schedule(A, B, bn, lad)
    padded, _ = spgemm_hash.host_schedule(A, B, bn, lad, headroom=2.0)
    sizes = np.asarray(bn.bin_size)
    m_cap = next_bucket(m, minimum=8)
    for s, e, p in zip(sizes, exact, padded):
        if not s:
            assert e == 0 and p == 0
            continue
        assert e >= int(s) and e & (e - 1) == 0      # pow-2, admits count
        assert p >= min(m_cap, 2 * int(s)) and p <= m_cap


@pytest.mark.parametrize("dist", ["uniform", "powerlaw"])
def test_packed_symbolic_matches_unpacked(dist):
    """Row packing on the STANDALONE symbolic kernel (paper opt. 3): the
    packed launch — several pow-2 sub-tables per VMEM tile — must agree
    bitwise with the unpacked kernel and the oracle, on a tiny ladder
    whose small rungs actually pack (rows_per_block > 1)."""
    m = 96
    A, B = _pair(29, m, 160, 120, 8.0, 6.0, dist=dist)
    nprod = nprod_into_rpt(A, B)[:m]
    lad = make_ladder((32, 64, 128), 1.2, (32, 64, 128))
    assert max(lad.rows_per_block) > 1      # packing actually engages
    bn = bin_rows_for_ladder(nprod, lad)
    packed = spgemm_hash.symbolic_binned(A, B, bn, lad, prod_capacity=1,
                                         row_packing=True)
    unpacked = spgemm_hash.symbolic_binned(A, B, bn, lad, prod_capacity=1,
                                           row_packing=False)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(unpacked))
    np.testing.assert_array_equal(np.asarray(packed[:m]),
                                  kref.row_nnz_from_support(A, B))


def test_packed_scheduled_symbolic_under_jit():
    """Schedule-driven packed symbolic (the engine's two-pass hot path
    form) traces cleanly and matches the oracle; buckets are floored to
    whole packs so every rung divides into grid steps."""
    m = 96
    A, B = _pair(9, m, 200, 150, 10.0, 8.0, dist="powerlaw")
    nprod = nprod_into_rpt(A, B)[:m]
    lad = make_ladder((32, 64, 128), 1.2, (32, 64, 128))
    bn = bin_rows_for_ladder(nprod, lad)
    row_buckets, fall_cap = spgemm_hash.host_schedule(
        A, B, bn, lad, packs=lad.rows_per_block)
    for b, cap in enumerate(row_buckets):
        if cap and b < len(lad.rows_per_block):
            assert cap % lad.rows_per_block[b] == 0

    @jax.jit
    def sym(A, B, bn):
        return spgemm_hash.symbolic_scheduled(
            A, B, bn, lad, row_buckets=row_buckets,
            fallback_prod_capacity=fall_cap, row_packing=True)

    nnz_buf, _, _ = sym(A, B, bn)
    np.testing.assert_array_equal(np.asarray(nnz_buf[:m]),
                                  kref.row_nnz_from_support(A, B))


def test_numeric_epilogue_sorted_and_complete():
    m, k, n = 32, 32, 32
    A, B = _pair(33, m, k, n, 4.0, 4.0)
    nnz_buf = esc.symbolic(A, B, prod_capacity=2048)
    rpt = exclusive_sum_in_place(nnz_buf)
    cap = next_bucket(int(rpt[-1]))
    lad = numeric_ladder(2.0)
    bn = bin_rows_for_ladder(nnz_buf[:m], lad)
    C = spgemm_hash.numeric_binned(A, B, rpt, bn, lad, prod_capacity=1,
                                   nnz_capacity=cap)
    rptn, coln = np.asarray(C.rpt), np.asarray(C.col)
    for i in range(m):
        seg = coln[rptn[i]:rptn[i + 1]]
        assert (np.diff(seg) > 0).all()
