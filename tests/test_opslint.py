"""opslint (repro.analysis_static) — rule fixtures + baseline regression.

Each rule family gets a bad fixture (must flag), a clean fixture (must
stay silent), and a suppressed fixture (`# opslint: disable=...`).
Fixtures are plain text analyzed by AST — nothing here executes JAX.
The final test pins the shipped ``opslint_baseline.json`` to a fresh
run over ``src/repro`` so the CI gate can never drift silently.
"""

import json
import textwrap
from pathlib import Path

from repro.analysis_static import (
    diff_against_baseline,
    load_baseline,
    run_paths,
)
from repro.analysis_static.__main__ import main as opslint_main

REPO_ROOT = Path(__file__).resolve().parent.parent


def lint(tmp_path, source, name="fixture.py", rules=None):
    (tmp_path / name).write_text(textwrap.dedent(source), encoding="utf-8")
    return run_paths([str(tmp_path)], root=str(tmp_path), rules=rules)


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# TRC — trace-safety
# ---------------------------------------------------------------------------

TRC_BAD = """
    import jax
    import numpy as np

    @jax.jit
    def bad(x):
        if x > 0:
            x = x + 1
        host = np.asarray(x)
        return int(x) + host.shape[0]
"""

TRC_CLEAN = """
    from functools import partial
    import jax
    import jax.numpy as jnp

    @partial(jax.jit, static_argnames=("m",))
    def good(x, m):
        if m:
            x = x + 1
        vals = None
        vals = vals if vals is None else vals
        return jnp.where(x > 0, x, 0)

    def host_only(x):
        return int(x)
"""

TRC_SUPPRESSED = """
    import jax

    @jax.jit
    def tolerated(x):
        if x > 0:  # opslint: disable=TRC002 -- trace-time constant in tests
            x = x + 1
        return x
"""


def test_trc_flags_host_sync_and_branch(tmp_path):
    findings = lint(tmp_path, TRC_BAD)
    assert "TRC001" in rules_of(findings)
    assert "TRC002" in rules_of(findings)
    # int(x) and np.asarray(x) are two separate syncs
    assert sum(f.rule == "TRC001" for f in findings) == 2


def test_trc_clean_static_branch_and_host_code(tmp_path):
    findings = lint(tmp_path, TRC_CLEAN)
    assert rules_of(findings) == []


def test_trc_suppressed_inline(tmp_path):
    findings = lint(tmp_path, TRC_SUPPRESSED)
    assert rules_of(findings) == []


def test_trc_propagates_through_call_graph(tmp_path):
    findings = lint(tmp_path, """
        import jax

        def helper(y):
            if y > 0:
                return y
            return -y

        @jax.jit
        def entry(x):
            return helper(x)
    """)
    assert [f.rule for f in findings] == ["TRC002"]


def test_trc_static_args_do_not_taint_callees(tmp_path):
    # schedule tuples threaded through a traced driver stay static
    findings = lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        def driver(x, buckets):
            for cap in buckets:
                if not cap:
                    continue
                x = x + cap
            return x

        @jax.jit
        def entry(x):
            return driver(x, (8, 16))
    """)
    assert rules_of(findings) == []


# ---------------------------------------------------------------------------
# DON — donation discipline
# ---------------------------------------------------------------------------

DON_BAD = """
    import jax

    def f(buf):
        return buf * 2

    g = jax.jit(f, donate_argnums=0)

    def use(buf):
        out = g(buf)
        return buf + out
"""

DON_CLEAN = """
    import jax

    def f(buf):
        return buf * 2

    g = jax.jit(f, donate_argnums=0)

    def use(buf):
        buf = g(buf)
        return buf
"""

DON_SUPPRESSED = """
    import jax

    def f(buf):
        return buf * 2

    g = jax.jit(f, donate_argnums=0)

    def use(buf):
        out = g(buf)
        return buf + out  # opslint: disable=DON001 -- interpret-mode test
"""


def test_don_flags_read_after_donation(tmp_path):
    findings = lint(tmp_path, DON_BAD)
    assert [f.rule for f in findings] == ["DON001"]
    assert "donated at line" in findings[0].message


def test_don_clean_rebind_idiom(tmp_path):
    assert lint(tmp_path, DON_CLEAN) == []


def test_don_suppressed_inline(tmp_path):
    assert lint(tmp_path, DON_SUPPRESSED) == []


def test_don_decorated_def_and_attribute_chain(tmp_path):
    findings = lint(tmp_path, """
        from functools import partial
        import jax

        @partial(jax.jit, donate_argnums=(1,))
        def fill(sizes, buf):
            return buf.at[0].set(sizes)

        def use(lease, sizes):
            out = fill(sizes, lease.i32)
            return lease.i32 + out
    """)
    assert [f.rule for f in findings] == ["DON001"]
    assert "`lease.i32`" in findings[0].message


# ---------------------------------------------------------------------------
# LCK — lock order / guarded fields
# ---------------------------------------------------------------------------

LCK_BAD = """
    import threading

    class Box:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0  # guarded-by: _lock

        def bump(self):
            self.count += 1
"""

LCK_CLEAN = """
    import threading

    class Box:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0  # guarded-by: _lock

        def bump(self):
            with self._lock:
                self.count += 1

        def _bump_locked(self):
            self.count += 1
"""

LCK_SUPPRESSED = """
    import threading

    class Box:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0  # guarded-by: _lock

        def bump_unsafe(self):
            self.count += 1  # opslint: disable=LCK002 -- single-thread path
"""

LCK_CYCLE = """
    import threading

    class Alpha:
        def __init__(self, other: "Beta" = None):
            self._lock = threading.Lock()
            self.other = other

        def poke(self):
            with self._lock:
                self.other.poke()

    class Beta:
        def __init__(self, other: "Alpha" = None):
            self._lock = threading.Lock()
            self.other = other

        def poke(self):
            with self._lock:
                self.other.poke()
"""

LCK_ORDERED = """
    import threading

    class Alpha:
        def __init__(self, other: "Beta" = None):
            self._lock = threading.Lock()
            self.other = other

        def poke(self):
            with self._lock:
                self.other.poke()

    class Beta:
        def __init__(self):
            self._lock = threading.Lock()

        def poke(self):
            with self._lock:
                pass
"""


def test_lck_flags_unlocked_guarded_write(tmp_path):
    findings = lint(tmp_path, LCK_BAD)
    assert [f.rule for f in findings] == ["LCK002"]
    assert "guarded-by: _lock" in findings[0].message


def test_lck_clean_with_lock_and_locked_convention(tmp_path):
    assert lint(tmp_path, LCK_CLEAN) == []


def test_lck_suppressed_inline(tmp_path):
    assert lint(tmp_path, LCK_SUPPRESSED) == []


def test_lck_detects_lock_order_cycle(tmp_path):
    findings = lint(tmp_path, LCK_CYCLE)
    assert [f.rule for f in findings] == ["LCK001"]
    assert "Alpha._lock" in findings[0].message
    assert "Beta._lock" in findings[0].message


def test_lck_one_directional_nesting_is_clean(tmp_path):
    assert lint(tmp_path, LCK_ORDERED) == []


def test_lck_mutator_call_counts_as_write(tmp_path):
    findings = lint(tmp_path, """
        import threading

        class Roster:
            def __init__(self):
                self._lock = threading.Lock()
                self._members = []  # guarded-by: _lock

            def add(self, m):
                self._members.append(m)
    """)
    assert [f.rule for f in findings] == ["LCK002"]


# ---------------------------------------------------------------------------
# INT — host-int width
# ---------------------------------------------------------------------------

INT_BAD = """
    import jax

    def tally(x):
        fetched = jax.device_get(x)
        total_bytes = 0
        total_bytes += fetched[0] * 8
        return total_bytes
"""

INT_CLEAN = """
    import jax

    def tally(x):
        fetched = jax.device_get(x)
        total_bytes = 0
        total_bytes += int(fetched[0]) * 8
        return total_bytes
"""

INT_SUPPRESSED = """
    import jax

    def tally(x):
        fetched = jax.device_get(x)
        total_bytes = 0
        total_bytes += fetched[0] * 8  # opslint: disable=INT001 -- tiny fixture counts
        return total_bytes
"""


def test_int_flags_unwidened_accumulator(tmp_path):
    findings = lint(tmp_path, INT_BAD)
    assert [f.rule for f in findings] == ["INT001"]
    assert "total_bytes" in findings[0].message


def test_int_clean_when_widened_at_fetch(tmp_path):
    assert lint(tmp_path, INT_CLEAN) == []


def test_int_suppressed_inline(tmp_path):
    assert lint(tmp_path, INT_SUPPRESSED) == []


# ---------------------------------------------------------------------------
# KRN — kernel budgets
# ---------------------------------------------------------------------------

KRN_BAD = """
    BAD_TABLE_SIZES = (16, 24)
    FOO_ENTRIES = 192
"""

KRN_CLEAN = """
    GOOD_TABLE_SIZES = (16, 32)
    PACK_TILE_ENTRIES = 8 * 128
    lowercase_sizes = (3, 5)
"""

KRN_SUPPRESSED = """
    # opslint: disable=KRN001 -- deliberately shaved sizes (paper Table 2)
    BAD_TABLE_SIZES = (15, 31)
    BIG_ENTRIES = 128 * 1024  # opslint: disable=KRN002 -- HBM-resident table
"""


def test_krn_flags_non_pow2_and_lane_misaligned(tmp_path):
    findings = lint(tmp_path, KRN_BAD)
    assert rules_of(findings) == ["KRN001", "KRN002"]


def test_krn_clean_constants_with_folding(tmp_path):
    assert lint(tmp_path, KRN_CLEAN) == []


def test_krn_suppressed_inline(tmp_path):
    assert lint(tmp_path, KRN_SUPPRESSED) == []


def test_krn_flags_over_budget_entries(tmp_path):
    findings = lint(tmp_path, "HUGE_ENTRIES = 128 * 1024\n")
    assert [f.rule for f in findings] == ["KRN002"]
    assert "VMEM" in findings[0].message


# ---------------------------------------------------------------------------
# engine: baseline diffing + CLI
# ---------------------------------------------------------------------------

LCK_BAD_TWICE = LCK_BAD + """
        def bump_again(self):
            self.count += 1
"""


def test_fail_on_new_diffs_against_baseline(tmp_path, capsys):
    fixture = tmp_path / "mod.py"
    fixture.write_text(textwrap.dedent(LCK_BAD), encoding="utf-8")
    baseline = tmp_path / "base.json"

    # write a baseline containing the finding -> gate passes
    rc = opslint_main([str(fixture), "--root", str(tmp_path),
                       "--write-baseline", str(baseline)])
    assert rc == 0
    rc = opslint_main([str(fixture), "--root", str(tmp_path),
                       "--fail-on-new", "--baseline", str(baseline)])
    assert rc == 0

    # a NEW finding (second unlocked write) must fail the gate
    fixture.write_text(textwrap.dedent(LCK_BAD_TWICE), encoding="utf-8")
    rc = opslint_main([str(fixture), "--root", str(tmp_path),
                       "--fail-on-new", "--baseline", str(baseline)])
    assert rc == 1
    out = capsys.readouterr().out
    assert "1 new" in out


def test_json_format_is_machine_readable(tmp_path, capsys):
    fixture = tmp_path / "mod.py"
    fixture.write_text(textwrap.dedent(INT_BAD), encoding="utf-8")
    rc = opslint_main([str(fixture), "--root", str(tmp_path),
                       "--format", "json"])
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["count"] == 1
    (finding,) = payload["findings"]
    assert finding["rule"] == "INT001"
    assert finding["line"] > 0 and finding["hint"]


def test_rule_selection(tmp_path):
    findings = lint(tmp_path, TRC_BAD, rules=["TRC002"])
    assert rules_of(findings) == ["TRC002"]


def test_diff_against_baseline_reports_fixed(tmp_path):
    findings = lint(tmp_path, LCK_BAD)
    assert len(findings) == 1
    stale = findings + [findings[0].__class__(
        rule="LCK002", path="gone.py", line=9, col=0,
        message="no longer reproduces")]
    new, fixed = diff_against_baseline(findings, stale)
    assert new == []
    assert [f.path for f in fixed] == ["gone.py"]


# ---------------------------------------------------------------------------
# regression: the shipped baseline matches a fresh run over src/repro
# ---------------------------------------------------------------------------

def test_shipped_baseline_matches_fresh_run():
    findings = run_paths([str(REPO_ROOT / "src" / "repro")],
                         root=str(REPO_ROOT))
    baseline = load_baseline(REPO_ROOT / "opslint_baseline.json")
    new, fixed = diff_against_baseline(findings, baseline)
    assert new == [], (
        "opslint found NEW findings vs the checked-in baseline — fix them "
        "or (for documented false positives) suppress inline:\n"
        + "\n".join(f.format_text() for f in new))
    assert fixed == [], (
        "baseline entries no longer reproduce — refresh "
        "opslint_baseline.json with scripts/opslint --write-baseline")


def test_guarded_by_ground_truth_is_present():
    """The PR's annotation satellite: the four lock-holding subsystems
    carry guarded-by annotations (ground truth for LCK002)."""
    expectations = {
        "src/repro/core/workspace.py": "bytes_in_use",
        "src/repro/engine/cache.py": "_entries",
        "src/repro/engine/telemetry.py": "_metrics",
        "src/repro/serve/spgemm_service.py": "_http",
    }
    for rel, field in expectations.items():
        text = (REPO_ROOT / rel).read_text(encoding="utf-8")
        guarded = [ln for ln in text.splitlines()
                   if "guarded-by:" in ln and field in ln]
        assert guarded, f"{rel}: expected a guarded-by annotation on {field}"
