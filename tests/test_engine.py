"""Engine subsystem tests: plans, cache, batched executor, retraces."""
import jax
import numpy as np
import pytest

from repro.core import CSR, SpgemmConfig, next_bucket, random_csr, spgemm
from repro.core.spgemm import spgemm_reference
from repro.engine import (MatrixSig, PlanCache, SpgemmEngine, plan, plan_key,
                          total_traces)
from repro.engine.executor import default_engine


def _pair(seed, m=32, k=28, n=36, da=3.0, db=3.0, dist="uniform"):
    A = random_csr(jax.random.PRNGKey(seed), m, k, avg_nnz_per_row=da,
                   distribution=dist)
    B = random_csr(jax.random.PRNGKey(seed + 1), k, n, avg_nnz_per_row=db,
                   distribution=dist)
    return A, B


def _sigs(A, B):
    return MatrixSig.of(A), MatrixSig.of(B)


# ---------------------------------------------------------------------------
# Plan signatures.
# ---------------------------------------------------------------------------

def test_matrix_sig_bucketing():
    A, _ = _pair(1)
    sig = MatrixSig.of(A)
    assert sig.nrows == A.nrows and sig.ncols == A.ncols
    assert sig.cap_bucket == next_bucket(A.capacity)
    # Padding within the bucket does not change the signature.
    assert MatrixSig.of(A.with_capacity(sig.cap_bucket)) == sig
    # Crossing the bucket boundary does.
    assert MatrixSig.of(A.with_capacity(2 * sig.cap_bucket)) != sig


def test_plan_signature_equality_and_hashing():
    A, B = _pair(3)
    a_sig, b_sig = _sigs(A, B)
    cfg = SpgemmConfig()
    p1, p2 = plan(a_sig, b_sig, cfg), plan(a_sig, b_sig, cfg)
    assert p1 == p2
    assert hash(p1) == hash(p2)
    assert p1.signature == plan_key(A, B, cfg)
    # Config is part of the identity.
    p3 = plan(a_sig, b_sig, SpgemmConfig(method="hash"))
    assert p3 != p1 and p3.signature != p1.signature
    # Specialization learns buckets without changing the cache identity.
    sp = p1.with_capacities(1024, 512)
    assert sp.is_specialized and not p1.is_specialized
    assert sp.signature == p1.signature
    assert sp.admits(A, B)


def test_plan_rejects_mismatched_shapes():
    A, B = _pair(5)
    with pytest.raises(AssertionError):
        plan(MatrixSig.of(B), MatrixSig.of(A), SpgemmConfig())


# ---------------------------------------------------------------------------
# Plan cache.
# ---------------------------------------------------------------------------

def test_plan_cache_hit_miss_evict():
    cfg = SpgemmConfig()
    cache = PlanCache(capacity=2)
    plans = []
    for m in (8, 16, 24):
        A, B = _pair(m, m=m)
        plans.append(plan(*_sigs(A, B), cfg))

    assert cache.get(plans[0].signature) is None          # miss
    e0 = cache.insert(plans[0])
    assert cache.get(plans[0].signature) is e0            # hit
    cache.insert(plans[1])
    cache.insert(plans[2])                                # evicts plans[0] (LRU)
    assert len(cache) == 2
    assert cache.evictions == 1
    assert plans[0].signature not in cache
    assert plans[2].signature in cache
    assert cache.get(plans[0].signature) is None          # miss again
    assert cache.hits == 1 and cache.misses == 2

    # Re-specialization drops the stale executable.
    e2 = cache.get(plans[2].signature)
    e2.executable = lambda *a: None
    cache.specialize(e2, plans[2].with_capacities(64, 64))
    assert e2.executable is None and e2.plan.is_specialized


def test_plan_cache_lru_order_refresh():
    cfg = SpgemmConfig()
    cache = PlanCache(capacity=2)
    pa = plan(*_sigs(*_pair(8, m=8)), cfg)
    pb = plan(*_sigs(*_pair(16, m=16)), cfg)
    pc = plan(*_sigs(*_pair(24, m=24)), cfg)
    cache.insert(pa)
    cache.insert(pb)
    cache.get(pa.signature)       # refresh pa -> pb becomes LRU
    cache.insert(pc)
    assert pa.signature in cache
    assert pb.signature not in cache


# ---------------------------------------------------------------------------
# Executor vs dense oracle.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["uniform", "powerlaw", "banded"])
def test_engine_matches_oracle_cold_and_hot(dist):
    engine = SpgemmEngine()
    A, B = _pair(7, dist=dist)
    ref = np.asarray(spgemm_reference(A, B))
    r_cold = engine.execute(A, B)       # steps path (learns buckets)
    r_hot = engine.execute(A, B)        # jitted steady-state path
    np.testing.assert_allclose(np.asarray(r_cold.C.to_dense()), ref,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_hot.C.to_dense()), ref,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(r_cold.C.rpt),
                                  np.asarray(r_hot.C.rpt))
    assert r_cold.total_nnz == r_hot.total_nnz
    entry = next(iter(engine.cache.items()))[1]
    assert entry.stats.steps_calls == 1 and entry.stats.hot_calls == 1


def test_engine_batched_drain_matches_oracle():
    engine = SpgemmEngine()
    # Mixed stream: two shape buckets interleaved.
    reqs = []
    for s in range(6):
        A, B = _pair(40 + s, m=24 if s % 2 else 32)
        reqs.append((engine.submit(A, B), A, B))
    results = engine.drain()
    assert len(results) == len(reqs)
    for uid, A, B in reqs:
        ref = np.asarray(spgemm_reference(A, B))
        np.testing.assert_allclose(np.asarray(results[uid].C.to_dense()),
                                   ref, rtol=1e-5, atol=1e-5)
    assert engine.stats.requests == 6
    assert len(engine.cache) == 2          # one plan per shape bucket


def test_drain_bounds_inflight_at_window():
    """Regression for the drain() off-by-one: dispatching before reaping
    held ``window + 1`` records in flight.  The bound is a device-memory
    budget, so it must hold at the moment of dispatch — count live
    records across dispatch/finalize and pin the peak at ``window``."""

    class Probe(SpgemmEngine):
        live = 0
        peak = 0

        def _dispatch(self, *a, **k):
            rec = super()._dispatch(*a, **k)
            self.live += 1
            self.peak = max(self.peak, self.live)
            return rec

        def _finalize(self, rec):
            out = super()._finalize(rec)
            self.live -= 1
            return out

    engine = Probe()
    A, B = _pair(130)
    engine.execute(A, B)                  # specialize: dispatches go async
    cap_a, cap_b = MatrixSig.of(A).cap_bucket, MatrixSig.of(B).cap_bucket
    reqs = []
    for s in range(9):
        A2, B2 = _pair(140 + s)
        reqs.append((engine.submit(A2.with_capacity(cap_a),
                                   B2.with_capacity(cap_b)), A2, B2))
    engine.live = engine.peak = 0
    results = engine.drain(window=3)
    assert engine.peak <= 3               # was window + 1 = 4 before the fix
    assert engine.stats.peak_inflight <= 3
    assert len(results) == len(reqs)
    for uid, A2, B2 in reqs:
        np.testing.assert_allclose(np.asarray(results[uid].C.to_dense()),
                                   np.asarray(spgemm_reference(A2, B2)),
                                   rtol=1e-5, atol=1e-5)
    # Degenerate window values still drain everything.
    engine.submit(A, B)
    assert len(engine.drain(window=1)) == 1


def test_engine_drain_overlaps_requests():
    engine = SpgemmEngine()
    A, B = _pair(60)
    engine.execute(A, B)                   # specialize the plan
    cap_a, cap_b = MatrixSig.of(A).cap_bucket, MatrixSig.of(B).cap_bucket
    for s in range(4):
        A2, B2 = _pair(70 + s)
        engine.submit(A2.with_capacity(cap_a), B2.with_capacity(cap_b))
    engine.drain()
    # Hot-path requests k+1 were planned while k executed on device.
    assert engine.stats.overlapped >= 3


# ---------------------------------------------------------------------------
# Retrace / capacity-bucket behavior.
# ---------------------------------------------------------------------------

def test_repeated_shape_triggers_zero_retraces():
    engine = SpgemmEngine()
    A, B = _pair(80)
    cap_a, cap_b = MatrixSig.of(A).cap_bucket, MatrixSig.of(B).cap_bucket
    engine.execute(A, B)                   # cold: steps path, no hot trace
    engine.execute(A, B)                   # first hot call: exactly 1 trace
    baseline = total_traces()
    for s in range(3):                     # distinct same-bucket matrices
        A2, B2 = _pair(90 + s)
        r = engine.execute(A2.with_capacity(cap_a), B2.with_capacity(cap_b))
        ref = np.asarray(spgemm_reference(A2, B2))
        np.testing.assert_allclose(np.asarray(r.C.to_dense()), ref,
                                   rtol=1e-5, atol=1e-5)
    assert total_traces() == baseline      # zero retraces on repeats
    assert engine.stats.capacity_grows == 0
    assert engine.cache.hits >= 4


@pytest.mark.parametrize("dist", ["uniform", "powerlaw"])
def test_hash_engine_matches_oracle_cold_and_hot(dist):
    """The hash method now has a jitted steady state, like ESC."""
    engine = SpgemmEngine(SpgemmConfig(method="hash"))
    A, B = _pair(7, dist=dist)
    ref = np.asarray(spgemm_reference(A, B))
    r_cold = engine.execute(A, B)       # steps path (learns the schedule)
    r_hot = engine.execute(A, B)        # jitted steady-state path
    np.testing.assert_allclose(np.asarray(r_cold.C.to_dense()), ref,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_hot.C.to_dense()), ref,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(r_cold.C.rpt),
                                  np.asarray(r_hot.C.rpt))
    assert r_cold.total_nnz == r_hot.total_nnz
    entry = next(iter(engine.cache.items()))[1]
    assert entry.stats.steps_calls == 1 and entry.stats.hot_calls == 1
    assert entry.plan.hash_schedule is not None


def test_hash_repeated_shape_triggers_zero_retraces():
    """Zero-retrace regression for the hash steady state (mirrors the ESC
    one above): after warmup, same-bucket repeats reuse ONE executable.

    Warmup covers rung DISCOVERY: a rung the first matrix left empty is
    learned as statically absent, so the first stream member that
    populates it costs one schedule grow (+1 retrace on the rebuild) —
    the documented bin-count-bucketing trade-off.  The steady-state
    guarantee starts once the schedule has seen the stream's rungs.
    """
    engine = SpgemmEngine(SpgemmConfig(method="hash"))
    A, B = _pair(80)
    cap_a, cap_b = MatrixSig.of(A).cap_bucket, MatrixSig.of(B).cap_bucket

    def run(seed):
        A2, B2 = _pair(seed)
        r = engine.execute(A2.with_capacity(cap_a), B2.with_capacity(cap_b))
        ref = np.asarray(spgemm_reference(A2, B2))
        np.testing.assert_allclose(np.asarray(r.C.to_dense()), ref,
                                   rtol=1e-5, atol=1e-5)

    seeds = (90, 91, 92, 93)
    engine.execute(A, B)                   # cold: steps path, no hot trace
    for s in seeds:                        # warmup pass: rung discovery may
        run(s)                             #   grow the schedule (retraces ok)
    run(seeds[0])                          # rebuild after any final grow
    baseline = total_traces()
    grows = engine.stats.capacity_grows
    for s in seeds:                        # replay: monotone schedule growth
        run(s)                             #   admits everything seen before
    assert total_traces() == baseline      # zero retraces on the replay
    assert engine.stats.capacity_grows == grows   # and zero further grows
    entry = next(iter(engine.cache.items()))[1]
    assert entry.stats.hot_calls >= 5      # replay served from the hot path


def test_hash_bin_bucket_growth_on_overflow():
    """A same-signature request whose rows land in a rung the schedule
    learned as empty must be detected (truncated hot run), redone via the
    steps path, and must grow the schedule so the NEXT call is hot."""
    m = 64
    d_small = np.zeros((m, m), np.float32)
    d_small[np.arange(m), np.arange(m)] = 1.0      # 1 nnz/row -> tiny nprod
    d_big = np.zeros((m, m), np.float32)
    d_big[:, :32] = 1.0                            # 32 nnz/row -> bigger rung
    dB = np.eye(m, dtype=np.float32)               # 1 nnz/row keeps nprod=nnzA
    A_small = CSR.from_dense(d_small).with_capacity(2048)
    A_big = CSR.from_dense(d_big)                  # capacity 2048 naturally
    Bc = CSR.from_dense(dB)
    assert MatrixSig.of(A_small) == MatrixSig.of(A_big)

    engine = SpgemmEngine(SpgemmConfig(method="hash"))
    engine.execute(A_small, Bc)
    engine.execute(A_small, Bc)            # hot path established
    sched0 = next(iter(engine.cache.items()))[1].plan.hash_schedule
    assert sched0.sym_row_buckets[1] == 0  # rung 1 statically absent

    r = engine.execute(A_big, Bc)          # same plan, rows overflow rung 0
    np.testing.assert_allclose(np.asarray(r.C.to_dense()), d_big @ dB,
                               rtol=1e-5)
    assert engine.stats.capacity_grows == 1
    assert engine.stats.bin_overflows == 1
    sched1 = next(iter(engine.cache.items()))[1].plan.hash_schedule
    assert sched1.sym_row_buckets[1] >= 64       # rung 1 now scheduled
    assert sched1.sym_row_buckets[0] >= sched0.sym_row_buckets[0]  # monotone

    r2 = engine.execute(A_big, Bc)         # grown schedule now holds (hot)
    np.testing.assert_allclose(np.asarray(r2.C.to_dense()), d_big @ dB,
                               rtol=1e-5)
    assert engine.stats.capacity_grows == 1
    # The small request still runs correctly under the grown plan.
    r3 = engine.execute(A_small, Bc)
    np.testing.assert_allclose(np.asarray(r3.C.to_dense()), d_small @ dB,
                               rtol=1e-5)


def test_prewarm_skips_cold_discovery():
    engine = SpgemmEngine()
    A, B = _pair(120)
    engine.prewarm(A, B, prod_bucket=4096, nnz_bucket=4096)
    r = engine.execute(A, B)               # first real call is already hot
    ref = np.asarray(spgemm_reference(A, B))
    np.testing.assert_allclose(np.asarray(r.C.to_dense()), ref,
                               rtol=1e-5, atol=1e-5)
    entry = next(iter(engine.cache.items()))[1]
    assert entry.stats.hot_calls == 1 and entry.stats.steps_calls == 0
    assert engine.stats.capacity_grows == 0
    # Prewarming never shrinks learned buckets.
    p = engine.prewarm(A, B, prod_bucket=16, nnz_bucket=16)
    assert p.prod_bucket == 4096 and p.nnz_bucket == 4096


def test_capacity_bucket_growth_under_pressure():
    engine = SpgemmEngine()
    d_small = np.zeros((8, 8), np.float32)
    d_small[0, :3] = 1.0                   # 3 nnz -> tiny learned buckets
    d_big = np.ones((8, 8), np.float32)    # 64 nnz -> overflows them
    dB = np.ones((8, 8), np.float32)
    A_small = CSR.from_dense(d_small).with_capacity(64)
    A_big = CSR.from_dense(d_big)          # capacity 64: same signature
    Bc = CSR.from_dense(dB)
    assert MatrixSig.of(A_small) == MatrixSig.of(A_big)

    engine.execute(A_small, Bc)
    engine.execute(A_small, Bc)            # hot path established
    r = engine.execute(A_big, Bc)          # same plan, bigger product
    np.testing.assert_allclose(np.asarray(r.C.to_dense()), d_big @ dB,
                               rtol=1e-5)
    assert engine.stats.capacity_grows == 1
    r2 = engine.execute(A_big, Bc)         # grown buckets now hold
    np.testing.assert_allclose(np.asarray(r2.C.to_dense()), d_big @ dB,
                               rtol=1e-5)
    assert engine.stats.capacity_grows == 1
    # The small request still runs correctly under the grown plan.
    r3 = engine.execute(A_small, Bc)
    np.testing.assert_allclose(np.asarray(r3.C.to_dense()), d_small @ dB,
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# The core API rides on the engine.
# ---------------------------------------------------------------------------

def test_spgemm_wrapper_routes_through_default_engine():
    A, B = _pair(99)
    before = default_engine().stats.requests
    res = spgemm(A, B)
    assert default_engine().stats.requests == before + 1
    # Public result surface is unchanged.
    for field in ("C", "total_nprod", "total_nnz", "sym_binning",
                  "num_binning", "timings"):
        assert hasattr(res, field)
    assert res.compression_ratio >= 1.0
