"""Adaptive execution policy (ISSUE 5): telemetry-driven shard count,
tracked-jitter hash-schedule headroom, fused-by-default fallback, and the
host-int64 policy/bucket math audit."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (CSR, SpgemmConfig, next_bucket, random_csr, spgemm,
                        spgemm_reference)
from repro.core.binning_ranges import symbolic_ladder
from repro.core.spgemm import AUTO_SHARDS
from repro.engine import (AdaptivePolicy, HashSchedule, MatrixSig,
                          PolicyState, SpgemmEngine, choose_shards,
                          clamp_shards, revise_shards, total_traces,
                          trim_schedule)
from repro.engine.autotune import trim_buckets, trim_fallback
from repro.kernels.spgemm_hash import (fallback_capacity_bucket,
                                       schedule_bucket)


def _pair(seed, m=32, k=28, n=36, da=3.0, db=3.0, dist="uniform"):
    A = random_csr(jax.random.PRNGKey(seed), m, k, avg_nnz_per_row=da,
                   distribution=dist)
    B = random_csr(jax.random.PRNGKey(seed + 1), k, n, avg_nnz_per_row=db,
                   distribution=dist)
    return A, B


# ---------------------------------------------------------------------------
# Shard-count selection (pure policy math).
# ---------------------------------------------------------------------------

def test_choose_shards_scales_with_flops_and_occupancy():
    pol = AdaptivePolicy(min_shard_flops=1000, max_shards=None)
    # Tiny products collapse to 1 (the merge finalizer would dominate).
    assert choose_shards(10, nrows=1000, devices=8, policy=pol) == 1
    assert choose_shards(999, nrows=1000, devices=8, policy=pol) == 1
    # Enough flops for 3 shards, but occupancy bounds the fan-out.
    assert choose_shards(3500, nrows=1000, devices=2, policy=pol) == 2
    assert choose_shards(3500, nrows=1000, devices=8, policy=pol) == 3
    # max_shards is a hard cap over the device count.
    cap = dataclasses.replace(pol, max_shards=2)
    assert choose_shards(10**9, nrows=1000, devices=8, policy=cap) == 2
    # Row feasibility: never more shards than the rows can carry.
    assert choose_shards(10**9, nrows=3, devices=8, policy=pol) == 1
    assert clamp_shards(8, 100) == 4 and clamp_shards(1, 5) == 1


def test_revise_shards_hysteresis_band():
    pol = AdaptivePolicy(min_shard_flops=1000, max_shards=4,
                         revise_period=2, revise_factor=2.0)
    state = PolicyState().with_shard_decision(4, 8000)
    # Window not full yet: no review.
    state = state.note_flops(7000)
    state, revised = revise_shards(state, 1000, 4, pol)
    assert not revised and state.flops_calls == 1
    # Mean inside [basis/2, basis*2]: window resets, decision holds.
    state = state.note_flops(5000)
    state, revised = revise_shards(state, 1000, 4, pol)
    assert not revised and state.shard_decision == 4
    assert state.flops_calls == 0
    # Sustained drift far below the band: shrink (here to 1).
    for f in (100, 120):
        state = state.note_flops(f)
    state, revised = revise_shards(state, 1000, 4, pol)
    assert revised and state.shard_decision == 1
    assert state.shard_basis == 110


def test_engine_auto_shards_shrink_to_one_on_tiny_products():
    """The acceptance scenario: a stream that turns tiny must stop
    fanning out — the policy revises N down to 1 from telemetry."""
    pol = AdaptivePolicy(min_shard_flops=1000, max_shards=2,
                         revise_period=2, revise_factor=2.0,
                         trim_streak=10**6)
    engine = SpgemmEngine(shards="auto", policy=pol)
    A, B = _pair(1, m=48, k=40, n=36, da=6.0, db=6.0)
    cap_a = next_bucket(A.capacity)
    d = np.zeros((48, 40), np.float32)
    d[:, 0] = 1.0                       # 1 nnz/row: a tiny product
    A_tiny = CSR.from_dense(d).with_capacity(cap_a)
    assert MatrixSig.of(A_tiny) == MatrixSig.of(A)   # same AUTO plan

    r = engine.execute(A, B)            # cold: decides N=2 from flops
    np.testing.assert_allclose(np.asarray(r.C.to_dense()),
                               np.asarray(spgemm_reference(A, B)),
                               rtol=1e-5, atol=1e-5)
    assert engine.stats.sharded_requests == 1
    auto_entry = engine.cache.get(
        (MatrixSig.of(A), MatrixSig.of(B),
         dataclasses.replace(engine.config, shards=AUTO_SHARDS)))
    assert auto_entry.plan.policy.shard_decision == 2

    ref_tiny = np.asarray(spgemm_reference(A_tiny, B))
    seen_sharded = engine.stats.sharded_requests
    for _ in range(4):                  # tiny stream: mean flops collapses
        r = engine.execute(A_tiny, B)
        np.testing.assert_allclose(np.asarray(r.C.to_dense()), ref_tiny,
                                   rtol=1e-5, atol=1e-5)
    assert engine.stats.policy_revisions == 1
    assert auto_entry.plan.policy.shard_decision == 1
    # The last request(s) ran unsharded: the sharded counter stopped.
    assert engine.stats.sharded_requests < seen_sharded + 4
    r = engine.execute(A_tiny, B)
    assert engine.stats.sharded_requests < engine.stats.auto_requests
    np.testing.assert_allclose(np.asarray(r.C.to_dense()), ref_tiny,
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Tracked-jitter headroom: trim derivation + the engine loop.
# ---------------------------------------------------------------------------

def test_trim_buckets_shrink_drop_and_pack_floor():
    current = (64, 32, 16, 0, 8)
    # Observed maxima over the streak: rung 1 only ever held 9 rows, rung
    # 2 was never populated, rung 4 (fallback) unseen as well.
    maxima = (55, 9, 0, 0, 0)
    out = trim_buckets(maxima, current, m=64, headroom=1.5)
    assert out == (64, 16, 0, 0, 0)     # shrink, drop, never grow
    # Pack floors win over the derived bucket (packed fused rungs).
    out = trim_buckets(maxima, current, m=64, headroom=1.5,
                       packs=(1, 32, 1, 1))
    assert out == (64, 32, 0, 0, 0)
    # Fallback capacity trims while any fallback rung stays active, 0
    # when every rung dropped (the shared sym/num bucket).
    assert trim_fallback(100, 4096, 1.5, active=False) == 0
    assert trim_fallback(100, 4096, 1.5, active=True) == 256
    assert trim_fallback(0, 4096, 1.5, active=True) == 4096  # conservative


def test_trim_schedule_noop_returns_none():
    sched = HashSchedule(sym_row_buckets=(16, 0, 0, 0, 0, 0, 0, 0, 0),
                         num_row_buckets=(16, 0, 0, 0, 0, 0, 0, 0),
                         fall_prod_bucket=0)
    state = PolicyState(streak=8,
                        sym_max=(9, 0, 0, 0, 0, 0, 0, 0, 0),
                        num_max=(9, 0, 0, 0, 0, 0, 0, 0))
    pol = AdaptivePolicy()
    out = trim_schedule(state, sched, m=16, sym_ladder=symbolic_ladder(1.2),
                        packed=False, fused=False, policy=pol)
    assert out is None                  # 16 is already the floor bucket


def test_engine_headroom_shrinks_on_stable_stream_zero_retraces():
    """Stable stream: after the trim streak, the schedule re-derives at a
    shrunken headroom (one deliberate retrace), then stays zero-retrace —
    padded grid steps actually go away."""
    m = 64
    d = np.zeros((m, m), np.float32)
    d[:9, :30] = 1.0                    # 9 rows -> sym rung 1 (27..426)
    d[9:, 0] = 1.0                      # 55 rows -> sym rung 0
    A = CSR.from_dense(d)
    Bc = CSR.from_dense(np.eye(m, dtype=np.float32))
    pol = AdaptivePolicy(trim_streak=3)
    engine = SpgemmEngine(SpgemmConfig(method="hash"), policy=pol)
    oracle = SpgemmEngine(SpgemmConfig(method="hash", fuse_numeric=False))
    ref = oracle.execute(A, Bc)

    engine.execute(A, Bc)               # cold (learns 2x-headroom schedule)
    entry = next(iter(engine.cache.items()))[1]
    sched0 = entry.plan.hash_schedule
    assert sched0.sym_row_buckets[1] == 32      # 9 rows @ 2x -> 32
    for _ in range(3):                  # eviction-free streak -> trim
        engine.execute(A, Bc)
    assert engine.stats.schedule_trims == 1
    sched1 = entry.plan.hash_schedule
    assert sched1.sym_row_buckets[1] == 16      # 9 rows @ 1.5x -> 16
    assert entry.plan.policy.headroom == pytest.approx(1.5)
    assert entry.plan.policy.trimmed            # one trim per epoch

    r = engine.execute(A, Bc)           # one rebuild trace for the trim
    baseline = total_traces()
    grows = engine.stats.capacity_grows
    for _ in range(4):                  # stable stream: zero retraces after
        r = engine.execute(A, Bc)
    assert total_traces() == baseline
    assert engine.stats.capacity_grows == grows
    assert engine.stats.schedule_trims == 1     # no trim oscillation
    nnz = ref.total_nnz
    assert r.total_nnz == nnz                   # bitwise vs two-pass oracle
    np.testing.assert_array_equal(np.asarray(r.C.rpt), np.asarray(ref.C.rpt))
    np.testing.assert_array_equal(np.asarray(r.C.col)[:nnz],
                                  np.asarray(ref.C.col)[:nnz])
    np.testing.assert_array_equal(np.asarray(r.C.val)[:nnz],
                                  np.asarray(ref.C.val)[:nnz])


def test_headroom_grows_on_overflow_and_trims_rearm():
    """Overflow doubles the tracked headroom (capped) and re-arms the trim
    epoch; the redone stream is correct."""
    m = 64
    d_small = np.zeros((m, m), np.float32)
    d_small[np.arange(m), np.arange(m)] = 1.0
    d_big = np.zeros((m, m), np.float32)
    d_big[:, :32] = 1.0
    dB = np.eye(m, dtype=np.float32)
    A_small = CSR.from_dense(d_small).with_capacity(2048)
    A_big = CSR.from_dense(d_big)
    Bc = CSR.from_dense(dB)
    assert MatrixSig.of(A_small) == MatrixSig.of(A_big)

    engine = SpgemmEngine(SpgemmConfig(method="hash"))
    engine.execute(A_small, Bc)
    engine.execute(A_small, Bc)                 # hot path established
    entry = next(iter(engine.cache.items()))[1]
    r = engine.execute(A_big, Bc)               # schedule overflow
    np.testing.assert_allclose(np.asarray(r.C.to_dense()), d_big @ dB,
                               rtol=1e-5)
    assert engine.stats.bin_overflows == 1
    assert entry.plan.policy.headroom == pytest.approx(4.0)  # 2x grown
    assert not entry.plan.policy.trimmed and entry.plan.policy.streak == 0


def test_capacity_only_overflow_keeps_headroom():
    """A pure nnz-capacity overflow (bins all admitted) must grow the
    pow-2 buckets but NOT inflate the bin headroom — the bins never
    jittered, and 4x-padded grid steps would be pure waste."""
    m, k = 8, 32
    d_small = np.zeros((m, k), np.float32)
    d_small[:, :2] = 1.0                 # nprod 2/row -> rung 0, tiny nnz
    d_big = np.zeros((m, k), np.float32)
    d_big[:, :26] = 1.0                  # nprod 26/row -> STILL rung 0
    A_small = CSR.from_dense(d_small).with_capacity(256)
    A_big = CSR.from_dense(d_big).with_capacity(256)
    Bc = CSR.from_dense(np.eye(k, dtype=np.float32))
    assert MatrixSig.of(A_small) == MatrixSig.of(A_big)

    engine = SpgemmEngine(SpgemmConfig(method="hash"))
    engine.execute(A_small, Bc)
    engine.execute(A_small, Bc)          # hot path established
    entry = next(iter(engine.cache.items()))[1]
    r = engine.execute(A_big, Bc)        # nnz outgrows the bucket only
    np.testing.assert_allclose(np.asarray(r.C.to_dense()),
                               d_big @ np.eye(k, dtype=np.float32),
                               rtol=1e-5)
    assert engine.stats.capacity_grows == 1
    assert engine.stats.bin_overflows == 0
    assert entry.plan.policy.headroom == pytest.approx(2.0)  # untouched


def test_fused_is_hash_default_and_falls_back_to_two_pass():
    """fuse_numeric=True is the hash default; when ``admits_fused`` fails
    the request is redone on the two-pass steps oracle automatically and
    the next same-signature call is hot again."""
    assert SpgemmConfig().fuse_numeric is True
    m = 64
    d_small = np.zeros((m, m), np.float32)
    d_small[np.arange(m), np.arange(m)] = 1.0
    d_big = np.zeros((m, m), np.float32)
    d_big[:, :32] = 1.0
    dB = np.eye(m, dtype=np.float32)
    A_small = CSR.from_dense(d_small).with_capacity(2048)
    A_big = CSR.from_dense(d_big)
    Bc = CSR.from_dense(dB)

    engine = SpgemmEngine(SpgemmConfig(method="hash"))
    assert engine.config.fuse_numeric
    engine.execute(A_small, Bc)
    engine.execute(A_small, Bc)
    entry = next(iter(engine.cache.items()))[1]
    assert entry.stats.hot_calls == 1 and entry.stats.steps_calls == 1

    r = engine.execute(A_big, Bc)       # fused verify fails -> steps redo
    np.testing.assert_allclose(np.asarray(r.C.to_dense()), d_big @ dB,
                               rtol=1e-5)
    assert engine.stats.bin_overflows == 1
    assert entry.stats.steps_calls == 2          # the two-pass fallback ran
    r2 = engine.execute(A_big, Bc)      # grown schedule: fused + hot again
    np.testing.assert_allclose(np.asarray(r2.C.to_dense()), d_big @ dB,
                               rtol=1e-5)
    assert entry.stats.steps_calls == 2 and entry.stats.hot_calls >= 2


# ---------------------------------------------------------------------------
# Integer-width audit: policy/bucket math is host int64 (Python int).
# ---------------------------------------------------------------------------

def test_policy_accumulators_survive_near_int31_flop_stream():
    """A stream of near-2^31-flop requests: the telemetry accumulators and
    the shard review must widen, not wrap (the ``2 * nprod`` guard of
    ``core/analysis.row_flops``, applied to the policy layer)."""
    big = 2**31 - 7                     # one request ~ int32 max
    state = PolicyState().with_shard_decision(2, big)
    for _ in range(8):
        state = state.note_flops(np.int64(big))
    assert state.flops_total == 8 * big          # > 2^34: wrapped math fails
    assert state.mean_flops == big > 0
    pol = AdaptivePolicy(min_shard_flops=1 << 20, max_shards=8,
                         revise_period=8, revise_factor=1.0 + 1e-9)
    state, revised = revise_shards(state, nrows=10**6, devices=8, policy=pol)
    assert state.shard_basis == big              # exact, not negative


def test_bucket_math_survives_near_int31_counts():
    """Headroom growth (`next_bucket` doubling) on near-2^31 observed
    counts computes in host int: buckets come out positive pow-2 ABOVE
    the int32 range instead of wrapping."""
    big = 2**31 - 100
    b = schedule_bucket(np.int64(big), m_cap=2**40, headroom=2.0)
    assert b == 2**32 and b > 2**31              # widened, not wrapped
    assert schedule_bucket(big, m_cap=2**40, headroom=1.0) == 2**31
    fb = fallback_capacity_bucket(np.int64(big), headroom=2.0)
    assert fb == 2**32 > 0
    assert next_bucket(2 * big) == 2**32
    # choose_shards on a multi-billion-flop estimate.
    pol = AdaptivePolicy(min_shard_flops=1 << 30, max_shards=64)
    assert choose_shards(2**36, nrows=10**6, devices=64, policy=pol) == 64
    # Trimming with near-wrap maxima stays monotone and positive.
    out = trim_buckets((big,), (2**32,), m=2**40, headroom=2.0)
    assert out == (2**32,)


def test_spgemm_auto_shards_knob():
    A, B = _pair(7)
    r = spgemm(A, B, shards="auto")
    np.testing.assert_allclose(np.asarray(r.C.to_dense()),
                               np.asarray(spgemm_reference(A, B)),
                               rtol=1e-5, atol=1e-5)
