"""Structured telemetry layer: spans, metrics, exporters, engine wiring.

Covers the observability acceptance surface: registry-backed stats
(counter names, histogram bucket edges), span nesting under the sharded
fan-out, ring-buffer overflow accounting, exporter schema validity
(JSONL parses; Chrome trace_event validates), Prometheus exposition
content, the disabled-mode no-op guarantee, and the empty-state
edge cases of ``stats.render()`` and the exporters.
"""
import json

import jax
import pytest

from repro.core import SpgemmConfig, random_csr
from repro.engine import (LATENCY_BUCKETS_S, EngineStats, EventLog,
                          MetricsRegistry, PlanStats, SpgemmEngine,
                          Telemetry, plan_label, prometheus_text, render,
                          resolve_telemetry, validate_chrome_trace)
from repro.engine import stats as stats_mod
from repro.engine.telemetry import (NULL_SPAN, Span, git_rev, utc_now_iso)


def _pair(seed, m=32, k=28, n=36, avg=3.0):
    A = random_csr(jax.random.PRNGKey(seed), m, k, avg_nnz_per_row=avg)
    B = random_csr(jax.random.PRNGKey(seed + 1), k, n, avg_nnz_per_row=avg)
    return A, B


@pytest.fixture(scope="module")
def traced_engine():
    """One traced engine that served a small unsharded stream."""
    tel = Telemetry(enabled=True)
    engine = SpgemmEngine(SpgemmConfig(method="esc"), telemetry=tel)
    A, B = _pair(0)
    for _ in range(3):
        engine.submit(A, B)
    results = engine.drain()
    assert len(results) == 3
    return engine


@pytest.fixture(scope="module")
def sharded_traced_engine():
    """One traced engine that served a stream with shards=2 fan-out."""
    tel = Telemetry(enabled=True)
    engine = SpgemmEngine(SpgemmConfig(method="esc"), shards=2,
                          telemetry=tel)
    A, B = _pair(10, m=48, k=40, n=40)
    for _ in range(2):
        engine.submit(A, B)
    results = engine.drain()
    assert len(results) == 2
    return engine


# ---------------------------------------------------------------------------
# Metrics registry.
# ---------------------------------------------------------------------------

def test_registry_get_or_create_and_kinds():
    reg = MetricsRegistry()
    c = reg.counter("x_total")
    c.inc()
    c.inc(2)
    assert reg.counter("x_total") is c and c.value == 3
    g = reg.gauge("y")
    g.set(7)
    assert reg.get("y").value == 7
    h = reg.histogram("z_seconds")
    assert reg.get("missing") is None
    snap = reg.snapshot()
    assert snap["x_total"] == {"kind": "counter", "value": 3}
    assert snap["z_seconds"]["kind"] == "histogram"
    # A name registered as one kind cannot be fetched as another.
    with pytest.raises(AssertionError):
        reg.gauge("x_total")


def test_histogram_pow2_bucket_edges():
    # The fixed ladder is 2^-14 .. 2^6 seconds, strictly doubling.
    assert LATENCY_BUCKETS_S[0] == 2.0 ** -14
    assert LATENCY_BUCKETS_S[-1] == 2.0 ** 6
    assert all(b == 2 * a for a, b in zip(LATENCY_BUCKETS_S,
                                          LATENCY_BUCKETS_S[1:]))
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds")
    h.observe(2.0 ** -14)        # lands exactly ON the first edge
    h.observe(0.5)
    h.observe(1e9)               # +Inf overflow bucket
    assert h.count == 3
    assert h.counts[0] == 1      # on-edge observation is <= the edge
    assert h.counts[-1] == 1     # overflow accounted
    assert h.mean == pytest.approx((2.0 ** -14 + 0.5 + 1e9) / 3)
    # Prometheus rendering: cumulative buckets, le="+Inf" is the count.
    lines = reg.render_lines()
    assert "# TYPE lat_seconds histogram" in lines
    assert any(line.startswith('lat_seconds_bucket{le="+Inf"} 3')
               for line in lines)
    assert "lat_seconds_count 3" in lines


def test_empty_histogram_renders_without_division():
    reg = MetricsRegistry()
    reg.histogram("empty_seconds")
    assert reg.get("empty_seconds").mean == 0.0
    text = reg.render_prometheus()
    assert "empty_seconds_count 0" in text


# ---------------------------------------------------------------------------
# Registry-backed stats (the subsume-not-duplicate satellite).
# ---------------------------------------------------------------------------

def test_engine_stats_fields_are_registry_metrics():
    s = EngineStats()
    s.requests += 2
    s.peak_inflight = 5
    # The attribute and the registry metric are ONE number.
    assert s.registry.get("opsparse_engine_requests_total").value == 2
    assert s.registry.get("opsparse_engine_peak_inflight").value == 5
    # Every declared field resolves to a prefixed metric name.
    for field in EngineStats._COUNTERS:
        assert EngineStats.metric_name(field).startswith("opsparse_engine_")
        assert EngineStats.metric_name(field).endswith("_total")


def test_plan_stats_metric_names():
    s = PlanStats()
    s.time_s += 0.25
    assert s.registry.get("opsparse_plan_time_seconds_total").value == 0.25
    assert PlanStats.metric_name("calls") == "opsparse_plan_calls_total"


def test_stats_reset_clears_trace_counters():
    stats_mod.record_trace("some-plan-key")
    assert stats_mod.total_traces() >= 1
    stats_mod.reset()
    assert stats_mod.total_traces() == 0
    assert stats_mod.traces_for("some-plan-key") == 0


# ---------------------------------------------------------------------------
# Spans and the event log.
# ---------------------------------------------------------------------------

def test_span_nesting_and_uid_inheritance():
    tel = Telemetry(enabled=True)
    with tel.span("outer", uid=7) as outer:
        with tel.span("inner") as inner:
            assert tel.current_span() is inner
        tel.event("ping")
    spans = tel.finished_spans()
    assert [s["name"] for s in spans] == ["inner", "outer"]
    inner_d, outer_d = spans
    assert inner_d["parent_id"] == outer_d["span_id"]
    assert inner_d["uid"] == 7            # inherited from the parent
    assert outer_d["parent_id"] is None
    assert all(s["dur"] >= 0 for s in spans)
    events = [e for e in tel.events.snapshot() if e["type"] == "event"]
    assert events[0]["name"] == "ping"


def test_end_span_is_idempotent():
    tel = Telemetry(enabled=True)
    span = tel.start_span("once")
    tel.end_span(span)
    t1 = span.t1
    tel.end_span(span)
    assert span.t1 == t1
    assert len(tel.finished_spans()) == 1


def test_event_log_ring_overflow_accounting():
    log = EventLog(capacity=4)
    for i in range(10):
        log.append({"i": i})
    assert len(log) == 4
    assert log.appended == 10
    assert log.dropped == 6
    assert [e["i"] for e in log.snapshot()] == [6, 7, 8, 9]
    log.clear()
    assert len(log) == 0 and log.appended == 0 and log.dropped == 0


def test_disabled_mode_is_a_noop():
    tel = resolve_telemetry(None)
    assert not tel.enabled
    span = tel.span("anything", uid=1)
    assert span is NULL_SPAN
    with span as s:
        assert s.set(x=1) is s
    tel.end_span(span)
    tel.event("nothing", uid=2)
    assert len(tel.events) == 0 and tel.events.appended == 0
    assert tel.finished_spans() == []
    # resolve_telemetry never aliases registries across engines.
    assert resolve_telemetry(None).registry is not tel.registry
    assert resolve_telemetry(tel) is tel
    assert resolve_telemetry(True).enabled


# ---------------------------------------------------------------------------
# Engine integration: nested request pipeline spans.
# ---------------------------------------------------------------------------

def test_engine_spans_cover_the_pipeline(traced_engine):
    spans = traced_engine.telemetry.finished_spans()
    names = {s["name"] for s in spans}
    for required in ("drain", "request", "plan_lookup", "cold_steps",
                     "symbolic", "numeric", "dispatch", "verify_sync",
                     "finalize"):
        assert required in names, f"missing span {required!r}"
    by_id = {s["span_id"]: s for s in spans}
    # plan_lookup always nests under its request; kernel phases under
    # cold_steps; verify_sync under finalize.
    for child, parent in (("plan_lookup", "request"),
                          ("symbolic", "cold_steps"),
                          ("numeric", "cold_steps"),
                          ("verify_sync", "finalize")):
        cs = [s for s in spans if s["name"] == child]
        assert cs, child
        assert all(by_id[s["parent_id"]]["name"] == parent for s in cs)
    # Request latency histogram observed one sample per request.
    hist = traced_engine.telemetry.registry.get(
        "opsparse_request_latency_seconds")
    assert hist.count == traced_engine.stats.requests == 3


def test_engine_sharded_fanout_span_nesting(sharded_traced_engine):
    spans = sharded_traced_engine.telemetry.finished_spans()
    names = {s["name"] for s in spans}
    assert {"partition", "shard", "verify_slices", "shard_merge"} <= names
    request_ids = {s["span_id"] for s in spans if s["name"] == "request"}
    shard_spans = [s for s in spans if s["name"] == "shard"]
    # Two requests x two shards, each shard span a child of ITS request.
    assert len(shard_spans) == 4
    assert all(s["parent_id"] in request_ids for s in shard_spans)
    assert {s["attrs"]["shard"] for s in shard_spans} == {0, 1}
    # Shard sub-dispatches must not inflate the request histogram.
    hist = sharded_traced_engine.telemetry.registry.get(
        "opsparse_request_latency_seconds")
    assert hist.count == sharded_traced_engine.stats.requests == 2


def test_plan_cache_lifecycle_events():
    tel = Telemetry(enabled=True)
    engine = SpgemmEngine(SpgemmConfig(method="esc"), cache_capacity=1,
                          telemetry=tel)
    A, B = _pair(20)
    engine.execute(A, B)
    A2, B2 = _pair(22, m=16, k=16, n=16)
    engine.execute(A2, B2)          # evicts the first plan (capacity 1)
    events = {e["name"] for e in tel.events.snapshot()
              if e["type"] == "event"}
    assert {"plan_insert", "plan_specialize", "plan_evict"} <= events


# ---------------------------------------------------------------------------
# Exporters.
# ---------------------------------------------------------------------------

def test_jsonl_export_parses(traced_engine, tmp_path):
    path = tmp_path / "events.jsonl"
    n = traced_engine.telemetry.export_jsonl(path)
    lines = path.read_text().splitlines()
    assert len(lines) == n > 0
    rows = [json.loads(line) for line in lines]
    assert all(row["type"] in ("span", "event") for row in rows)


def test_chrome_trace_export_validates(traced_engine, tmp_path):
    path = tmp_path / "trace.json"
    payload = traced_engine.telemetry.export_chrome_trace(path)
    assert validate_chrome_trace(payload) == len(payload["traceEvents"])
    assert validate_chrome_trace(path) > 0       # re-read from disk
    # "X" complete events carry rebased non-negative microsecond stamps.
    xs = [e for e in payload["traceEvents"] if e["ph"] == "X"]
    assert xs and all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)
    # Parentage rides in args so Perfetto queries can rebuild the tree.
    assert all("span_id" in e["args"] for e in xs)


def test_validate_chrome_trace_rejects_bad_payloads():
    with pytest.raises(ValueError):
        validate_chrome_trace([])                    # wrong container
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X"}]})  # missing req
    bad_dur = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0, "pid": 1, "tid": 1, "dur": -1}]}
    with pytest.raises(ValueError):
        validate_chrome_trace(bad_dur)
    unmatched = {"traceEvents": [
        {"name": "a", "ph": "B", "ts": 0, "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError):
        validate_chrome_trace(unmatched)
    matched = {"traceEvents": [
        {"name": "a", "ph": "B", "ts": 0, "pid": 1, "tid": 1},
        {"name": "a", "ph": "E", "ts": 1, "pid": 1, "tid": 1}]}
    assert validate_chrome_trace(matched) == 2


def test_prometheus_text_content(traced_engine):
    text = prometheus_text(traced_engine)
    assert "# TYPE opsparse_engine_requests_total counter" in text
    assert "opsparse_engine_requests_total 3" in text
    assert "opsparse_plan_cache_hits_total" in text
    assert "opsparse_request_latency_seconds_bucket" in text
    # Per-plan samples are labeled; exactly ONE TYPE header per name.
    assert 'opsparse_plan_calls_total{plan="' in text
    assert text.count("# TYPE opsparse_plan_calls_total counter") == 1
    # Exposition text must not contain blank samples.
    assert all(line.startswith("#") or " " in line
               for line in text.strip().splitlines())


def test_prometheus_text_empty_engine():
    engine = SpgemmEngine(SpgemmConfig(method="esc"))
    text = prometheus_text(engine)
    assert "opsparse_engine_requests_total 0" in text
    assert "opsparse_plan_cache_size 0" in text


# ---------------------------------------------------------------------------
# render() guards + consumers.
# ---------------------------------------------------------------------------

def test_render_zero_state_has_no_division_errors():
    engine = SpgemmEngine(SpgemmConfig(method="esc"))
    out = render(engine)
    assert "0 requests" in out and "hit rate 0.0%" in out


def test_render_unspecialized_plan_and_telemetry_lines():
    tel = Telemetry(enabled=True)
    engine = SpgemmEngine(SpgemmConfig(method="esc"), telemetry=tel)
    # An inserted-but-never-executed plan has no buckets/policy/schedule.
    from repro.engine import MatrixSig, plan
    A, B = _pair(30)
    engine.cache.insert(plan(MatrixSig.of(A), MatrixSig.of(B),
                             engine.config))
    out = render(engine)
    assert "prod=None" in out
    assert "telemetry:" in out           # enabled engines report the ring
    engine.execute(A, B)
    out = render(engine)
    assert "latency: 1 finalized requests" in out
    assert plan_label(engine.cache.items()[0][1].plan) in out


def test_plan_label_shapes_and_shards():
    from repro.engine import MatrixSig, plan
    A, B = _pair(40)
    p = plan(MatrixSig.of(A), MatrixSig.of(B), SpgemmConfig(method="hash"))
    label = plan_label(p)
    assert label.startswith(f"{A.nrows}x{A.ncols}")
    assert label.endswith("/hash")
    p2 = plan(MatrixSig.of(A), MatrixSig.of(B),
              SpgemmConfig(method="esc", shards=2))
    assert plan_label(p2).endswith("/sh2")


# ---------------------------------------------------------------------------
# Trajectory helpers.
# ---------------------------------------------------------------------------

def test_utc_timestamp_and_git_rev():
    ts = utc_now_iso()
    assert ts.endswith("Z") and "T" in ts and len(ts) == 20
    rev = git_rev("/root/repo")
    assert isinstance(rev, str) and rev
    assert git_rev("/") == "unknown"         # not a git repository
