"""Int8 weight-only serving: quantized params flow through prefill/decode
with bounded error; bytes halve."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import Model
from repro.models.quant import (QTensor, abstract_quantized, dequant_tree,
                                quantize_params)


def _tree_bytes(tree):
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "zamba2-1.2b"])
def test_quantized_prefill_close_and_smaller(arch):
    cfg = get_arch(arch).reduced().replace(dtype="bfloat16")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_params(params, min_dim=8)

    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    lg_full, _ = model.prefill(params, {"tokens": tokens}, kv_cache_len=20)
    lg_q, caches = model.prefill(qparams, {"tokens": tokens},
                                 kv_cache_len=20)
    # random-init logits are near-uniform, so exact argmax agreement is
    # too strict; require high correlation of the logit vectors (the
    # production metric — greedy agreement — needs trained weights)
    a = np.asarray(lg_full[:, -1], np.float32).reshape(-1)
    b = np.asarray(lg_q[:, -1], np.float32).reshape(-1)
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.95, (arch, corr)

    # decode runs on the quantized tree
    lg2, _ = model.decode_step(qparams, tokens[:, :1], caches,
                               jnp.int32(16))
    assert np.isfinite(np.asarray(lg2, np.float32)).all()

    # resident weight bytes roughly halve (int8 vs bf16 + tiny scales)
    assert _tree_bytes(qparams) < 0.6 * _tree_bytes(params)


def test_quantize_round_trip_error_bounded():
    w = jax.random.normal(jax.random.PRNGKey(2), (4, 64, 32),
                          jnp.bfloat16)  # stacked layer param
    q = quantize_params({"w": w}, min_dim=8)["w"]
    assert isinstance(q, QTensor)
    assert q.scale.shape == (4, 1, 1)      # per-matrix-slice scales
    err = jnp.abs(q.dequant(jnp.float32) - w.astype(jnp.float32))
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)))
    assert float(err.max()) <= float(amax) / 127.0 + 1e-6


def test_abstract_quantized_mirrors_shapes():
    cfg = get_arch("qwen3-1.7b").reduced()
    model = Model(cfg)
    ab = abstract_quantized(model.abstract_params(), min_dim=8)
    real = quantize_params(model.init(jax.random.PRNGKey(0)), min_dim=8)
    ab_l = jax.tree_util.tree_leaves(ab)
    real_l = jax.tree_util.tree_leaves(real)
    assert len(ab_l) == len(real_l)
    for a, r in zip(ab_l, real_l):
        assert tuple(a.shape) == tuple(r.shape), (a.shape, r.shape)
        assert str(a.dtype) == str(r.dtype)


def test_quantized_moe_runs():
    """MoE under int8: top-k routing makes logits sensitive to weight
    noise at random init, so only run+finiteness is asserted here (the
    router itself stays f32 by design)."""
    cfg = get_arch("olmoe-1b-7b").reduced().replace(dtype="bfloat16")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_params(params, min_dim=8)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    lg, _ = model.prefill(qparams, {"tokens": tokens}, kv_cache_len=20)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
