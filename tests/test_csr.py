import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CSR, random_csr
from repro.core.csr import gather_rows


def test_from_dense_round_trip():
    rng = np.random.default_rng(0)
    d = rng.standard_normal((17, 23)).astype(np.float32)
    d[rng.random((17, 23)) < 0.7] = 0.0
    A = CSR.from_dense(d)
    np.testing.assert_allclose(np.asarray(A.to_dense()), d)
    assert int(A.nnz()) == (d != 0).sum()


def test_row_ids_and_mask():
    d = np.zeros((4, 5), np.float32)
    d[0, 1] = 1.0
    d[0, 3] = 2.0
    d[2, 0] = 3.0
    A = CSR.from_dense(d)
    np.testing.assert_array_equal(np.asarray(A.row_ids()), [0, 0, 2])
    np.testing.assert_array_equal(np.asarray(A.nnz_per_row()), [2, 0, 1, 0])


def test_padding_preserves_semantics():
    d = np.eye(6, dtype=np.float32)
    A = CSR.from_dense(d).with_capacity(32)
    assert A.capacity == 32
    np.testing.assert_allclose(np.asarray(A.to_dense()), d)
    assert int(A.entry_mask().sum()) == 6


def test_empty_rows_and_empty_matrix():
    d = np.zeros((5, 5), np.float32)
    A = CSR.from_dense(d).with_capacity(8)
    np.testing.assert_allclose(np.asarray(A.to_dense()), d)
    assert int(A.nnz()) == 0


def test_random_csr_respects_limits():
    A = random_csr(jax.random.PRNGKey(0), 50, 40, avg_nnz_per_row=4.0,
                   max_nnz_per_row=9)
    per_row = np.asarray(A.nnz_per_row())
    assert per_row.max() <= 9
    col = np.asarray(A.col)
    rpt = np.asarray(A.rpt)
    for i in range(50):  # sorted, in-range columns
        seg = col[rpt[i]:rpt[i + 1]]
        assert (np.diff(seg) > 0).all()
        assert seg.size == 0 or (seg >= 0).all() and (seg < 40).all()


def test_gather_rows():
    A = random_csr(jax.random.PRNGKey(1), 30, 20, avg_nnz_per_row=3.0)
    rows = jnp.array([5, 2, 29, 7], jnp.int32)
    valid = jnp.array([True, True, True, False])
    sub = gather_rows(A, rows, valid)
    dense = np.asarray(A.to_dense())
    got = np.asarray(sub.to_dense())
    np.testing.assert_allclose(got[0], dense[5])
    np.testing.assert_allclose(got[1], dense[2])
    np.testing.assert_allclose(got[2], dense[29])
    np.testing.assert_allclose(got[3], 0.0)


def test_csr_is_pytree():
    A = random_csr(jax.random.PRNGKey(2), 8, 8, avg_nnz_per_row=2.0)
    leaves = jax.tree_util.tree_leaves(A)
    assert len(leaves) == 3
    B = jax.tree_util.tree_map(lambda x: x, A)
    assert B.shape == A.shape
