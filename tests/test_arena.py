"""Workspace arena, memory governor, and lease-lifecycle tests.

Covers the §5.3/§5.4 generalization: the process-wide size-bucketed
arena plans lease workspace from at dispatch (buffers donated through
the steady-state jit, returned/rebound at finalize), the governor's
degradation ladder (reclaim -> forced headroom trim -> fused two-pass
spill -> backpressure), arena-aware cache eviction (forfeit, no leak),
and dump/load rebinding loaded plans to the live arena.
"""
import json

import jax
import numpy as np
import pytest

from repro.core import SpgemmConfig, random_csr
from repro.core.spgemm import spgemm_reference
from repro.engine import (Arena, ArenaPressureError, HashSchedule, LeaseSpec,
                          MatrixSig, MemoryGovernor, SpgemmEngine,
                          total_traces)


def _pair(seed, m=32, k=28, n=36, da=3.0, db=3.0, dist="uniform"):
    A = random_csr(jax.random.PRNGKey(seed), m, k, avg_nnz_per_row=da,
                   distribution=dist)
    B = random_csr(jax.random.PRNGKey(seed + 1), k, n, avg_nnz_per_row=db,
                   distribution=dist)
    return A, B


@pytest.fixture(scope="module")
def heavy_pair():
    """A pair dense enough that hash plans carry a nonzero fallback
    bucket (rows overflowing the largest hash rung) — the hash lease."""
    return _pair(51, 32, 1024, 768, 80.0, 64.0, dist="powerlaw")


def _check(result, A, B):
    np.testing.assert_allclose(np.asarray(result.C.to_dense()),
                               np.asarray(spgemm_reference(A, B)),
                               rtol=1e-5, atol=1e-5)


def _lease_bytes(spec):
    return sum(Arena._bucket_bytes(k) for k in Arena._buckets(spec))


# ---------------------------------------------------------------------------
# Arena unit accounting.
# ---------------------------------------------------------------------------

def test_arena_accounting_roundtrip():
    ar = Arena()
    spec = LeaseSpec(i32_cells=100, val_cells=50, val_dtype="float32")
    nbytes = _lease_bytes(spec)          # pow-2 buckets: 128 + 64 cells
    assert nbytes == 4 * 128 + 4 * 64

    l1 = ar.acquire(spec)
    assert l1.active
    assert ar.bytes_in_use == ar.bytes_reserved == ar.peak_bytes == nbytes
    assert (ar.lease_misses, ar.lease_hits) == (2, 0)

    ar.release(l1)
    assert not l1.active
    assert ar.bytes_in_use == 0 and ar.bytes_free == nbytes
    ar.release(l1)                       # idempotent
    assert ar.bytes_free == nbytes

    l2 = ar.acquire(spec)                # same buckets -> pure free-list hit
    assert (ar.lease_misses, ar.lease_hits) == (2, 2)
    assert ar.bytes_reserved == nbytes == ar.peak_bytes
    assert ar.hit_rate == 0.5
    ar.release(l2)

    assert ar.reclaim() == nbytes
    assert ar.bytes_reserved == 0
    assert ar.peak_bytes == nbytes       # high-water mark survives reclaim
    ar.reset_peak()
    assert ar.peak_bytes == 0


def test_arena_cap_binds_new_bytes_only():
    ar = Arena()
    spec = LeaseSpec(i32_cells=64, val_cells=64, val_dtype="float32")
    nbytes = _lease_bytes(spec)
    assert ar.try_acquire(spec, cap_bytes=nbytes - 1) is None
    lease = ar.acquire(spec, cap_bytes=nbytes)
    ar.release(lease)
    # A spec fully served from the free lists always succeeds, even over
    # an already-exceeded cap — reuse never adds bytes.
    assert ar.try_acquire(spec, cap_bytes=0) is not None
    with pytest.raises(ArenaPressureError):
        ar.acquire(LeaseSpec(4096, 4096, "float32"), cap_bytes=nbytes)


def test_forfeit_drops_accounting_without_recycling():
    ar = Arena()
    spec = LeaseSpec(i32_cells=64, val_cells=64, val_dtype="float32")
    lease = ar.acquire(spec)
    nbytes = ar.bytes_in_use
    assert ar.forfeit(lease) == nbytes
    assert ar.bytes_in_use == 0
    assert ar.bytes_free == 0            # buffers NOT recycled
    assert ar.forfeit(lease) == 0        # idempotent
    ar.release(lease)                    # late finalize: no-op
    assert ar.bytes_free == 0 and ar.bytes_in_use == 0


def test_lease_rebind_recycles_the_returned_arrays():
    ar = Arena()
    spec = LeaseSpec(i32_cells=64, val_cells=64, val_dtype="float32")
    lease = ar.acquire(spec)
    new_i32 = jax.numpy.ones(128, dtype="int32")
    new_val = jax.numpy.ones(64, dtype="float32")
    ar.release(lease, rebind=(new_i32, new_val))
    relent = ar.acquire(spec)            # hit: must hand back the rebinds
    assert relent.i32 is new_i32 and relent.val is new_val


# ---------------------------------------------------------------------------
# Engine steady state: leases reused, zero retraces, gauges fresh.
# ---------------------------------------------------------------------------

def test_steady_state_reuses_one_lease_without_retrace():
    A, B = _pair(61)
    ar = Arena()
    eng = SpgemmEngine(SpgemmConfig(method="esc"), arena=ar)
    eng.execute(A, B)                    # cold: steps path, no lease
    assert ar.bytes_reserved == 0
    _check(eng.execute(A, B), A, B)      # first hot call allocates the lease
    assert ar.lease_misses == 2 and ar.bytes_in_use == 0
    nbytes = ar.bytes_reserved
    assert nbytes > 0

    t0, misses0 = total_traces(), ar.lease_misses
    for _ in range(4):
        _check(eng.execute(A, B), A, B)
    assert total_traces() == t0          # donation didn't retrace
    assert ar.lease_misses == misses0    # every lease a free-list hit
    assert ar.lease_hits == 8
    assert ar.bytes_reserved == nbytes   # one parked lease, not five
    assert ar.bytes_in_use == 0

    from repro.engine import prometheus_text
    text = prometheus_text(eng)
    assert f"opsparse_arena_bytes_reserved {nbytes}" in text
    assert f"opsparse_arena_peak_bytes {nbytes}" in text
    assert "opsparse_arena_lease_hits_total 8" in text


# ---------------------------------------------------------------------------
# Governor degradation ladder.
# ---------------------------------------------------------------------------

def test_governor_backpressure_when_ladder_exhausted():
    A, B = _pair(63)
    ar = Arena()
    eng = SpgemmEngine(SpgemmConfig(method="esc"), arena=ar,
                       governor=MemoryGovernor(cap_bytes=0))
    eng.execute(A, B)                    # cold steps path needs no lease
    # ESC has no trim (hash-only) or spill (fused-only) rung: refuse.
    with pytest.raises(ArenaPressureError):
        eng.execute(A, B)
    assert eng.stats.arena_pressure >= 1
    assert ar.pressure_events >= 1
    assert ar.bytes_in_use == 0          # nothing leaked on the way out


def test_drain_backpressure_caps_peak_at_one_lease():
    A, B = _pair(65)
    ar = Arena()
    eng = SpgemmEngine(SpgemmConfig(method="esc"), arena=ar)
    eng.execute(A, B)
    eng.execute(A, B)                    # steady: one lease parked
    cap = ar.bytes_reserved
    eng.governor = MemoryGovernor(cap_bytes=cap)
    ar.reset_peak()

    uids = [eng.submit(A, B) for _ in range(5)]
    results = eng.drain(window=4)
    assert set(results) == set(uids)
    for uid in uids:
        _check(results[uid], A, B)
    # Backpressure finalized in-flight records instead of allocating:
    # the peak never exceeded the single-lease cap.
    assert ar.peak_bytes <= cap
    assert eng.stats.arena_pressure >= 1
    assert ar.bytes_in_use == 0

    # Ordered drain walks the same ladder.
    uids = [eng.submit(A, B) for _ in range(3)]
    results = eng.drain(drain_ordered=True)
    for uid in uids:
        _check(results[uid], A, B)
    assert ar.peak_bytes <= cap


def test_governor_forced_trim_shrinks_lease(heavy_pair):
    A, B = heavy_pair
    cfg = SpgemmConfig(method="hash")
    ar = Arena()
    eng = SpgemmEngine(cfg, arena=ar)
    eng.execute(A, B)
    eng.execute(A, B)
    entry = eng.cache.get((MatrixSig.of(A), MatrixSig.of(B), cfg))
    sched = entry.plan.hash_schedule
    assert sched.fall_prod_bucket > 0    # fallback rows present (the lease)
    cap = ar.bytes_reserved              # exactly the steady-state lease

    # Inflate the fallback bucket 4x, as if the schedule had been sized
    # by a much larger union partner, then cap the arena at the honest
    # size: rung 1 must re-derive the schedule from the streak's observed
    # maxima and fit back under the cap.
    eng.cache.specialize(entry, entry.plan.with_hash_schedule(HashSchedule(
        sched.sym_row_buckets, sched.num_row_buckets,
        4 * sched.fall_prod_bucket)))
    eng.governor = MemoryGovernor(cap_bytes=cap)
    _check(eng.execute(A, B), A, B)
    assert eng.stats.arena_trims == 1
    assert entry.plan.hash_schedule.fall_prod_bucket < 4 * sched.fall_prod_bucket
    assert _lease_bytes(entry.plan.workspace_spec()) <= cap

    # Post-trim steady state: no further pressure.
    pressure = eng.stats.arena_pressure
    _check(eng.execute(A, B), A, B)
    assert eng.stats.arena_pressure == pressure


def test_governor_spills_fused_to_two_pass(heavy_pair):
    A, B = heavy_pair
    cfg = SpgemmConfig(method="hash", fuse_numeric=True)
    ar = Arena()
    eng = SpgemmEngine(cfg, arena=ar)
    eng.execute(A, B)
    eng.execute(A, B)
    entry = eng.cache.get((MatrixSig.of(A), MatrixSig.of(B), cfg))
    assert entry.plan.workspace_spec() is not None

    eng.governor = MemoryGovernor(cap_bytes=0, trim_under_pressure=False)
    ar.reclaim()                         # park nothing: the cap must bind
    spilled = eng.execute(A, B)          # rung 2: unleased two-pass oracle
    assert eng.stats.arena_spills == 1
    assert ar.bytes_in_use == 0
    _check(spilled, A, B)
    # The fused executable stays cached for when pressure clears.
    assert entry.executable is not None
    eng.governor = MemoryGovernor()
    _check(eng.execute(A, B), A, B)
    assert eng.stats.arena_spills == 1   # leased fused path again


# ---------------------------------------------------------------------------
# Arena-aware cache eviction: no leak, in-flight leases forfeited.
# ---------------------------------------------------------------------------

def test_evict_forfeits_inflight_lease_without_leak():
    A, B = _pair(67)
    cfg = SpgemmConfig(method="esc")
    ar = Arena()
    eng = SpgemmEngine(cfg, arena=ar)
    eng.execute(A, B)
    eng.execute(A, B)
    key = (MatrixSig.of(A), MatrixSig.of(B), cfg)

    # Dispatch without finalizing: the lease is checked out (in flight).
    rec = eng._dispatch(next(eng._uids), A, B, cfg)
    assert ar.bytes_in_use > 0
    free_before = ar.bytes_free
    assert eng.cache.evict(key)
    # Forfeited: dropped from accounting but NOT recycled — the buffers
    # were donated into the still-running executable.
    assert ar.bytes_in_use == 0
    assert ar.bytes_free == free_before
    # The straggler finalize still verifies, and its release is a no-op.
    _check(eng._finalize(rec), A, B)
    assert ar.bytes_in_use == 0
    assert ar.bytes_free == free_before

    # Clearing a cache with parked (released) leases leaks nothing.
    eng.execute(A, B)
    eng.execute(A, B)
    eng.cache.clear()
    assert ar.bytes_in_use == 0


def test_evict_prefers_smaller_stamp_then_bigger_footprint():
    cfg = SpgemmConfig(method="esc")
    cache_engine = SpgemmEngine(cfg, arena=Arena(), cache_capacity=2)
    small = _pair(71, m=16, k=12, n=14)
    big = _pair(73, m=48, k=44, n=40, da=6.0, db=6.0)
    cache_engine.execute(*small)
    cache_engine.execute(*small)
    cache_engine.execute(*big)           # cache full: {small, big}
    key_small = (MatrixSig.of(small[0]), MatrixSig.of(small[1]), cfg)
    key_big = (MatrixSig.of(big[0]), MatrixSig.of(big[1]), cfg)
    cache_engine.execute(*small)         # small is now most recently used
    other = _pair(75, m=20, k=18, n=22)
    cache_engine.execute(*other)         # evicts big (older stamp)
    assert cache_engine.cache.get(key_small) is not None
    assert cache_engine.cache.get(key_big) is None


# ---------------------------------------------------------------------------
# Dump/load: loaded plans rebind to the live arena; v2 compat mapping.
# ---------------------------------------------------------------------------

def test_load_rebinds_plans_to_live_arena(tmp_path):
    A, B = _pair(77)
    cfg = SpgemmConfig(method="esc")
    a1 = Arena()
    warm = SpgemmEngine(cfg, arena=a1)
    warm.execute(A, B)
    warm.execute(A, B)
    reserved1 = a1.bytes_reserved
    path = str(tmp_path / "plans.json")
    assert warm.cache.dump(path) >= 1

    a2 = Arena()
    fresh = SpgemmEngine(cfg, arena=a2)
    assert fresh.cache.load(path) >= 1
    _check(fresh.execute(A, B), A, B)    # loaded plan: straight to hot path
    # The lease came from the NEW engine's arena, not the dump's origin.
    assert a2.lease_misses == 2 and a2.bytes_reserved > 0
    assert a1.bytes_reserved == reserved1
    fresh.cache.clear()
    assert a2.bytes_in_use == 0


def test_load_v2_dump_merges_fallback_buckets(tmp_path):
    A, B = _pair(79)
    cfg = SpgemmConfig(method="hash")
    warm = SpgemmEngine(cfg, arena=Arena())
    warm.execute(A, B)
    warm.execute(A, B)
    path = str(tmp_path / "plans.json")
    warm.cache.dump(path)

    blob = json.load(open(path))
    assert blob["version"] == 4
    blob["version"] = 2                  # pre-merge payload: split buckets
    for plan in blob["plans"]:
        hs = plan["hash_schedule"]
        del hs["fall_prod_bucket"]
        hs["sym_fall_prod_bucket"] = 1024
        hs["num_fall_prod_bucket"] = 4096
    json.dump(blob, open(path, "w"))

    fresh = SpgemmEngine(cfg, arena=Arena())
    assert fresh.cache.load(path) >= 1
    entry = fresh.cache.get((MatrixSig.of(A), MatrixSig.of(B), cfg))
    # v2's separate sym/num fallback buckets merge to their max.
    assert entry.plan.hash_schedule.fall_prod_bucket == 4096
