import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bin_rows, bin_rows_for_ladder, bin_rows_identity,
                        classify, make_ladder, numeric_ladder, symbolic_ladder)
from repro.core.binning_ranges import (NUMERIC_NOMINAL, SYMBOLIC_NOMINAL,
                                       NUMERIC_SWEEP, SYMBOLIC_SWEEP)


def test_paper_table1_symbolic_ranges():
    """Table 1 of the paper: sym_1.2x upper bounds must match exactly."""
    lad = symbolic_ladder(1.2)
    assert lad.upper == (26, 426, 853, 1706, 3413, 6826, 10240, 20480)


def test_paper_table2_numeric_ranges():
    """Table 2: num_2x upper bounds 16/128/256/512/1024/2048/4096."""
    lad = numeric_ladder(2.0)
    assert lad.upper == (16, 128, 256, 512, 1024, 2048, 4096)


def test_paper_table4_sym_sweep_ranges():
    """Table 4: sym_1x and sym_1.5x range grids."""
    assert symbolic_ladder(1.0).upper == (32, 512, 1024, 2048, 4096, 8192,
                                          12288, 24576)
    assert symbolic_ladder(1.5).upper == (21, 341, 682, 1365, 2730, 5461,
                                          8192, 16384)


def test_classify_first_admitting_rung():
    upper = (4, 16, 64)
    sizes = jnp.array([0, 4, 5, 16, 17, 64, 65, 1000])
    got = np.asarray(classify(sizes, upper))
    np.testing.assert_array_equal(got, [0, 0, 1, 1, 2, 2, 3, 3])


def test_bin_rows_partition_and_order():
    sizes = jnp.array([3, 100, 7, 0, 50, 2, 9, 700], jnp.int32)
    lad = make_ladder((8, 64), 1.0)
    b = bin_rows(sizes, upper=lad.upper, num_bins=lad.num_bins)
    bins = np.asarray(b.bins)
    # bins is a permutation of all row ids (the paper's min-metadata claim)
    np.testing.assert_array_equal(np.sort(bins), np.arange(8))
    # per-bin membership respects the ranges; in-bin order is stable (by id)
    np.testing.assert_array_equal(np.asarray(b.bin_size), [4, 2, 2])
    np.testing.assert_array_equal(np.asarray(b.bin_offset), [0, 4, 6])
    np.testing.assert_array_equal(bins[:4], [0, 2, 3, 5])
    np.testing.assert_array_equal(bins[4:6], [4, 6])
    np.testing.assert_array_equal(bins[6:], [1, 7])
    assert int(b.max_size) == 700


def test_fast_path_identity():
    """Alg 3: all rows fit bin0 -> bins == identity, pass 2 skipped."""
    sizes = jnp.full((10,), 3, jnp.int32)
    lad = make_ladder((8, 64), 1.0)
    b = bin_rows_for_ladder(sizes, lad)
    np.testing.assert_array_equal(np.asarray(b.bins), np.arange(10))
    np.testing.assert_array_equal(np.asarray(b.bin_size), [10, 0, 0])


def test_fast_path_not_taken_when_large_row():
    sizes = jnp.array([3, 3, 100], jnp.int32)
    lad = make_ladder((8, 64), 1.0)
    b = bin_rows_for_ladder(sizes, lad)
    assert int(b.bin_size[2]) == 1  # fallback rung used


def test_rows_of_bin_padding():
    sizes = jnp.array([1, 100, 1], jnp.int32)
    lad = make_ladder((8, 64), 1.0)
    b = bin_rows_for_ladder(sizes, lad)
    rows, cnt = b.rows_of_bin(0, capacity=8)
    assert int(cnt) == 2
    np.testing.assert_array_equal(np.asarray(rows)[:2], [0, 2])


@pytest.mark.parametrize("mult", SYMBOLIC_SWEEP)
def test_sym_sweep_ladders_constructible(mult):
    lad = symbolic_ladder(mult)
    assert len(lad.upper) == len(SYMBOLIC_NOMINAL)
    assert all(u <= t for u, t in zip(lad.upper, lad.table_sizes))


@pytest.mark.parametrize("mult", NUMERIC_SWEEP)
def test_num_sweep_ladders_constructible(mult):
    lad = numeric_ladder(mult)
    assert len(lad.upper) == len(NUMERIC_NOMINAL)
    # numeric tables are nominal-1 (paper keeps 4B for shared_offset);
    # ranges are computed from the nominal pow2 sizes
    assert all(u <= t + 1 for u, t in zip(lad.upper, lad.table_sizes))


def test_vmem_extended_ladder():
    lad = symbolic_ladder(1.2, vmem_extended=True)
    assert lad.table_sizes[-1] == 1048576
    assert lad.fallback_threshold() == int(1048576 / 1.2)
