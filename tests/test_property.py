"""Hypothesis property tests on the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import (CSR, SpgemmConfig, bin_rows, bin_rows_for_ladder,
                        make_ladder, spgemm)
from repro.core.binning import bin_by_id
from repro.models import moe as M

SETTINGS = dict(max_examples=20, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


@st.composite
def sparse_matrix(draw, max_dim=24):
    m = draw(st.integers(1, max_dim))
    n = draw(st.integers(1, max_dim))
    density = draw(st.floats(0.0, 0.5))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    d = rng.standard_normal((m, n)).astype(np.float32)
    d[rng.random((m, n)) >= density] = 0.0
    return d


@given(sparse_matrix(), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_csr_dense_round_trip(d, _):
    A = CSR.from_dense(d)
    np.testing.assert_allclose(np.asarray(A.to_dense()), d)


@given(sparse_matrix(), sparse_matrix())
@settings(**SETTINGS)
def test_spgemm_matches_dense_oracle(da, db):
    # make shapes compatible
    k = min(da.shape[1], db.shape[0])
    da, db = da[:, :k], db[:k, :]
    if k == 0:
        return
    A, B = CSR.from_dense(da), CSR.from_dense(db)
    res = spgemm(A, B, SpgemmConfig(method="esc"))
    np.testing.assert_allclose(np.asarray(res.C.to_dense()), da @ db,
                               rtol=1e-4, atol=1e-4)
    # two-phase invariant: rpt non-decreasing, nnz consistent
    rpt = np.asarray(res.C.rpt)
    assert (np.diff(rpt) >= 0).all()
    assert rpt[-1] == res.total_nnz


@given(st.lists(st.integers(0, 10_000), min_size=1, max_size=200))
@settings(**SETTINGS)
def test_binning_is_partition(sizes):
    """bins is always a permutation; members respect their rung ranges."""
    sizes = jnp.asarray(sizes, jnp.int32)
    lad = make_ladder((8, 64, 512), 1.2)
    b = bin_rows_for_ladder(sizes, lad)
    bins = np.asarray(b.bins)
    np.testing.assert_array_equal(np.sort(bins), np.arange(len(sizes)))
    sizes_np = np.asarray(sizes)
    bounds = list(lad.upper)
    bin_of = np.asarray(b.bin_of_row)
    for i, s in enumerate(sizes_np):
        k = bin_of[i]
        lo = bounds[k - 1] if k > 0 else -1
        hi = bounds[k] if k < len(bounds) else np.inf
        assert lo < s <= hi or (s == 0 and k == 0)
    # offsets are the exclusive sum of sizes
    np.testing.assert_array_equal(
        np.asarray(b.bin_offset),
        np.concatenate([[0], np.cumsum(np.asarray(b.bin_size))[:-1]]))


@given(st.lists(st.integers(0, 7), min_size=1, max_size=300))
@settings(**SETTINGS)
def test_bin_by_id_counting_sort(ids):
    """The MoE router invariant: stable counting sort by expert id."""
    ids_a = jnp.asarray(ids, jnp.int32)
    order, counts, offsets = bin_by_id(ids_a, 8)
    order = np.asarray(order)
    sorted_ids = np.asarray(ids)[order]
    assert (np.diff(sorted_ids) >= 0).all()          # grouped by expert
    np.testing.assert_array_equal(
        np.asarray(counts), np.bincount(ids, minlength=8))
    # stability: within one expert, original order preserved
    for e in range(8):
        members = order[sorted_ids == e]
        assert (np.diff(members) > 0).all()


@given(st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_moe_conservation_no_drop(seed):
    """With capacity >= S*k, MoE output == exact weighted expert mix."""
    cfg_like = __import__("repro.configs.base", fromlist=["ArchConfig"])
    cfg = cfg_like.ArchConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=8, vocab_size=32, num_experts=4,
        experts_per_token=2, moe_capacity_factor=16.0, dtype="float32")
    from repro.models.param import init_params
    p = init_params(M.moe_specs(cfg), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 6, 16))
    out, aux = M.moe(p, x, cfg)
    ref, aux2 = M.moe_dense_dispatch(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2,
                               atol=2e-3)


@given(st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_grad_compression_error_feedback(seed):
    """Error feedback keeps the long-run mean of compressed grads exact."""
    from repro.train.compression import quantize, dequantize
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(64).astype(np.float32) * 0.01)
    err = jnp.zeros(64)
    total_sent = jnp.zeros(64)
    steps = 50
    for _ in range(steps):
        q, s, err = quantize(g, err)
        total_sent = total_sent + dequantize(q, s)
    np.testing.assert_allclose(np.asarray(total_sent / steps),
                               np.asarray(g), atol=1e-4)
