"""Serving engine: continuous batching with per-slot positions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import Model
from repro.serve.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_arch("internlm2-1.8b").reduced().replace(
        num_layers=2, d_model=32, d_ff=64, vocab_size=50, num_heads=2,
        num_kv_heads=2, dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_engine_serves_batched_requests(tiny_lm):
    model, params = tiny_lm
    eng = ServingEngine(model, params, max_batch=3, max_len=48)
    rng = np.random.default_rng(0)
    for uid in range(7):   # more requests than slots -> continuous refill
        plen = int(rng.integers(3, 9))
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, 50, plen).astype(np.int32),
                           max_new_tokens=5))
    results = eng.run()
    assert sorted(results) == list(range(7))
    assert all(len(v) == 5 for v in results.values())


def test_engine_matches_sequential_decode(tiny_lm):
    """Tokens from the batched engine == single-request greedy decode."""
    model, params = tiny_lm
    prompt = np.array([3, 14, 15, 9, 2], np.int32)

    eng = ServingEngine(model, params, max_batch=2, max_len=32)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    # a second concurrent request with a DIFFERENT length exercises the
    # per-slot position path
    eng.submit(Request(uid=1, prompt=prompt[:3], max_new_tokens=6))
    got = eng.run()[0]

    # reference: pure prefill+decode loop, batch of 1
    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray(prompt)[None]}, kv_cache_len=32)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(5):
        lg, caches = model.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), caches,
            jnp.int32(pos))
        toks.append(int(jnp.argmax(lg[0, -1])))
        pos += 1
    assert got == toks


def test_engine_eos_stops_early(tiny_lm):
    model, params = tiny_lm
    eng = ServingEngine(model, params, max_batch=1, max_len=32)
    # run once to find the greedy token, then use it as eos
    eng.submit(Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                       max_new_tokens=4))
    first = eng.run()[0]
    eng2 = ServingEngine(model, params, max_batch=1, max_len=32)
    eng2.submit(Request(uid=1, prompt=np.array([1, 2, 3], np.int32),
                        max_new_tokens=8, eos_id=first[1]))
    out = eng2.run()[1]
    assert out[1] == first[1] and len(out) == 2


def test_engine_rejects_oversized_prompt_structurally(tiny_lm):
    """A prompt that can't fit max_len is rejected with ``req.error``
    set — the engine keeps serving the well-formed requests around it."""
    model, params = tiny_lm
    eng = ServingEngine(model, params, max_batch=2, max_len=16,
                        telemetry=True)
    rng = np.random.default_rng(1)
    good = [Request(uid=0, prompt=rng.integers(0, 50, 4).astype(np.int32),
                    max_new_tokens=3),
            Request(uid=2, prompt=rng.integers(0, 50, 5).astype(np.int32),
                    max_new_tokens=3)]
    bad = Request(uid=1, prompt=rng.integers(0, 50, 40).astype(np.int32))
    eng.submit(good[0])
    eng.submit(bad)            # between two well-formed requests
    eng.submit(good[1])
    results = eng.run()
    assert sorted(results) == [0, 1, 2]
    assert results[1] == [] and bad.done
    assert bad.error is not None and "max_len" in bad.error
    assert all(len(results[r.uid]) == 3 and r.error is None for r in good)
    reg = eng.telemetry.registry
    assert reg.counter("opsparse_serve_rejected_total").value == 1
    assert reg.counter("opsparse_serve_requests_total").value == 2
