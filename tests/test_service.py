"""Fault-tolerant serving front-end tests (serve/spgemm_service.py).

Exercises the request-level robustness contract over the deterministic
fault-injection layer (core/faults.py): injected lease denials walk the
retry ladder and recover BITWISE, injected verify overflows redo through
the steps oracle, deadlines return structured timeouts, non-transient
faults never retry, and per-tenant plan caches keep one tenant's churn
from evicting another's plans.  Everything runs the ESC method (cheap
jnp compiles) so the suite stays fast.
"""
import re
import urllib.request

import jax
import numpy as np
import pytest

from repro.core import SpgemmConfig, random_csr
from repro.core.faults import (FaultPlan, FaultSpec, InjectedFault,
                               NULL_FAULTS, resolve_faults)
from repro.core.workspace import Arena, ArenaPressureError
from repro.engine import MemoryGovernor, SpgemmEngine
from repro.engine.telemetry import Histogram, histogram_quantile
from repro.serve import ServiceResult, SpgemmService

CFG = SpgemmConfig(method="esc")


def _pair(seed, m=48, k=48, n=48, avg=4.0):
    A = random_csr(jax.random.PRNGKey(seed), m, k, avg_nnz_per_row=avg)
    B = random_csr(jax.random.PRNGKey(seed + 1), k, n, avg_nnz_per_row=avg)
    return A, B


def _assert_bitwise(r, ref):
    """Both results carry identical CSR payloads, bit for bit."""
    np.testing.assert_array_equal(np.asarray(r.C.rpt),
                                  np.asarray(ref.C.rpt))
    nnz = int(np.asarray(ref.C.rpt)[-1])
    np.testing.assert_array_equal(np.asarray(r.C.col)[:nnz],
                                  np.asarray(ref.C.col)[:nnz])
    np.testing.assert_array_equal(np.asarray(r.C.val)[:nnz],
                                  np.asarray(ref.C.val)[:nnz])


# ---------------------------------------------------------------------------
# FaultPlan scheduling semantics.
# ---------------------------------------------------------------------------

def test_fault_plan_at_indices_fire_deterministically():
    fp = FaultPlan([FaultSpec(site="lease_denial", at=(1, 3))])
    hits = [fp.fire("lease_denial") is not None for _ in range(5)]
    assert hits == [False, True, False, True, False]
    snap = fp.snapshot()
    assert snap["visits"]["lease_denial"] == 5
    assert snap["injected"]["lease_denial"] == 2


def test_fault_plan_probability_is_seed_deterministic():
    def run(seed):
        fp = FaultPlan([FaultSpec(site="executor_raise", probability=0.5)],
                       seed=seed)
        return [fp.fire("executor_raise") is not None for _ in range(32)]

    assert run(7) == run(7)
    assert run(7) != run(8)        # astronomically unlikely to collide


def test_fault_plan_count_bounds_injections():
    fp = FaultPlan([FaultSpec(site="verify_overflow", at=(0, 1, 2),
                              count=2)])
    hits = [fp.fire("verify_overflow") is not None for _ in range(4)]
    assert hits == [True, True, False, False]


def test_fault_plan_validation_and_resolve():
    with pytest.raises(ValueError):
        FaultSpec(site="nope")
    with pytest.raises(TypeError):
        resolve_faults("not a plan")
    assert resolve_faults(None) is NULL_FAULTS
    assert not NULL_FAULTS.enabled
    assert NULL_FAULTS.fire("lease_denial") is None


def test_histogram_quantile_conservative_edges():
    h = Histogram(buckets=(0.1, 0.2, 0.4))
    assert histogram_quantile(h, 0.99) is None      # empty: no basis
    for v in (0.05, 0.15, 0.15, 0.3):
        h.observe(v)
    assert histogram_quantile(h, 0.5) == 0.2        # rounded UP to edge
    assert histogram_quantile(h, 1.0) == 0.4
    h.observe(9.0)                                  # +Inf overflow bucket
    assert histogram_quantile(h, 1.0) == 0.8        # 2x top edge stand-in


# ---------------------------------------------------------------------------
# Engine-level injection: denial walks the real ladder, overflow redoes.
# ---------------------------------------------------------------------------

def test_injected_lease_denial_drains_and_retries_bitwise():
    A, B = _pair(0)
    ref = SpgemmEngine(CFG, arena=Arena()).execute(A, B)

    # Visits advance once per successful acquisition, once per ladder
    # attempt when denied.  Deny BOTH attempts of the second hot call
    # (visits: cold call=none, hot#1=1, hot#2 initial=2 + post-reclaim=3)
    # while work is queued: drain reaps the in-flight request to free
    # its lease and retries, so the batch still completes — bitwise.
    fp = FaultPlan([FaultSpec(site="lease_denial", at=(2, 3))])
    eng = SpgemmEngine(CFG, arena=Arena(), faults=fp)
    eng.execute(A, B)              # cold: specializes the plan
    eng.execute(A, B)              # hot #1: visit 1
    for _ in range(3):
        eng.submit(A, B)
    results = eng.drain()
    assert len(results) == 3
    for r in results.values():
        _assert_bitwise(r, ref)
    assert fp.injected["lease_denial"] == 2
    assert eng.stats.faults_injected == 2


def test_injected_verify_overflow_recovers_bitwise():
    A, B = _pair(2)
    ref = SpgemmEngine(CFG, arena=Arena()).execute(A, B)

    fp = FaultPlan([FaultSpec(site="verify_overflow", at=(0,))])
    eng = SpgemmEngine(CFG, arena=Arena(), faults=fp)
    eng.execute(A, B)              # cold: no verify visit
    grows_before = eng.stats.capacity_grows
    r = eng.execute(A, B)          # hot: forced overflow -> steps redo
    _assert_bitwise(r, ref)
    assert fp.injected["verify_overflow"] == 1
    assert eng.stats.capacity_grows > grows_before
    r2 = eng.execute(A, B)         # next call is clean again
    _assert_bitwise(r2, ref)


def test_injected_executor_raise_classification():
    A, B = _pair(4)
    fp = FaultPlan([FaultSpec(site="executor_raise", at=(0,),
                              message="poisoned")])
    eng = SpgemmEngine(CFG, arena=Arena(), faults=fp)
    with pytest.raises(InjectedFault, match="poisoned") as exc_info:
        eng.execute(A, B)
    assert not exc_info.value.transient
    # The engine survives the injected failure: next request succeeds.
    ref = SpgemmEngine(CFG, arena=Arena()).execute(A, B)
    _assert_bitwise(eng.execute(A, B), ref)


# ---------------------------------------------------------------------------
# Service-level contract.
# ---------------------------------------------------------------------------

def test_service_retries_injected_pressure_bitwise():
    A, B = _pair(6)
    ref = SpgemmService(CFG, arena=Arena()).call(A, B).value

    fp = FaultPlan([FaultSpec(site="lease_denial", at=(1, 2))])
    svc = SpgemmService(CFG, arena=Arena(), faults=fp,
                        backoff_base_s=1e-4)
    svc.call(A, B)                 # cold
    svc.call(A, B)                 # hot: visit 0 (clean)
    r = svc.call(A, B)             # hot: both attempts denied -> retry
    assert r.ok and r.retries == 1 and r.degraded == "reclaim"
    assert r.faults_survived == 2
    _assert_bitwise(r.value, ref)
    text = svc.prometheus_text()
    assert re.search(
        r'opsparse_service_retries_total\{tenant="default"\} 1', text)
    assert re.search(
        r'opsparse_service_faults_survived_total\{tenant="default"\} 2',
        text)


def test_service_nontransient_fault_does_not_retry():
    A, B = _pair(8)
    fp = FaultPlan([FaultSpec(site="executor_raise", at=(0,),
                              message="poisoned request")])
    svc = SpgemmService(CFG, arena=Arena(), faults=fp)
    r = svc.call(A, B)
    assert r.status == "error" and not r.ok
    assert r.retries == 0          # fatal => exactly one attempt
    assert "poisoned request" in r.error
    assert fp.injected["executor_raise"] == 1
    # The tenant keeps serving after the poisoned request.
    assert svc.call(A, B).ok


def test_service_transient_fault_retries_and_succeeds():
    A, B = _pair(10)
    fp = FaultPlan([FaultSpec(site="executor_raise", at=(0,),
                              transient=True, message="blip")])
    svc = SpgemmService(CFG, arena=Arena(), faults=fp,
                        backoff_base_s=1e-4)
    r = svc.call(A, B)
    assert r.ok and r.retries == 1
    assert r.faults_survived == 1


def test_service_deadline_admission_and_expiry():
    A, B = _pair(12)
    svc = SpgemmService(CFG, arena=Arena())
    assert svc.call(A, B).ok       # calibrates cold_s_per_flop

    # Up-front rejection: predicted latency exceeds an absurd budget.
    r = svc.call(_pair(14)[0], _pair(14)[1], deadline_s=1e-9)
    assert r.status == "timeout" and r.value is None
    assert "predicted" in r.error

    # Expiry during the request: an injected stall on a known-hot plan
    # admits (steady-state quantile is tiny) but blows the budget.
    fp = FaultPlan([FaultSpec(site="slow_dispatch", at=(1,),
                              delay_s=0.3)])
    svc2 = SpgemmService(CFG, arena=Arena(), faults=fp)
    assert svc2.call(A, B).ok      # builds latency history
    r = svc2.call(A, B, deadline_s=0.05)
    assert r.status == "timeout"
    text = svc2.prometheus_text()
    assert re.search(
        r'opsparse_service_timeouts_total\{tenant="default"\} 1', text)


def test_service_never_raises():
    A, B = _pair(16)
    # Every site armed at once, repeatedly; no exception may escape.
    fp = FaultPlan([
        FaultSpec(site="lease_denial", probability=0.3),
        FaultSpec(site="verify_overflow", probability=0.3),
        FaultSpec(site="executor_raise", probability=0.2, transient=True),
        FaultSpec(site="slow_dispatch", probability=0.2, delay_s=0.001),
    ], seed=3)
    svc = SpgemmService(CFG, arena=Arena(), faults=fp,
                        backoff_base_s=1e-4)
    statuses = [svc.call(A, B, deadline_s=30.0).status for _ in range(8)]
    assert set(statuses) <= {"ok", "timeout", "rejected", "error"}


def test_service_per_tenant_cache_isolation():
    # Tenant "small" has one plan; tenant "churn" floods its OWN cache
    # past capacity.  Isolation: churn's evictions never touch small's
    # plan, and the shared arena stays bounded by one governor.
    A, B = _pair(18)
    svc = SpgemmService(CFG, arena=Arena(), cache_capacity=2,
                        governor=MemoryGovernor(cap_bytes=256 << 20))
    assert svc.call(A, B, tenant="small").ok
    for i, m in enumerate((16, 24, 40, 72, 136)):   # distinct pow-2 sigs
        assert svc.call(*_pair(20 + i, m=m), tenant="churn").ok
    churn_engine = svc.engine("churn")
    small_engine = svc.engine("small")
    assert churn_engine.cache.evictions > 0
    assert small_engine.cache.evictions == 0
    assert len(small_engine.cache) == 1
    # And the hot path still works for the quiet tenant.
    assert svc.call(A, B, tenant="small").ok


def test_service_tenant_roster_admission():
    A, B = _pair(30)
    svc = SpgemmService(CFG, arena=Arena(), max_tenants=2)
    assert svc.call(A, B, tenant="a").ok
    assert svc.call(A, B, tenant="b").ok
    r = svc.call(A, B, tenant="c")
    assert r.status == "rejected" and r.retry_after_s is not None
    with pytest.raises(RuntimeError):
        svc.engine("d")
    assert svc.tenants() == ["a", "b"]


def test_service_session_batches():
    A, B = _pair(32)
    svc = SpgemmService(CFG, arena=Arena())
    ref = svc.call(A, B).value
    with svc.session() as sess:
        uids = [sess.submit(A, B) for _ in range(3)]
        results = sess.drain()
    assert sorted(results) == sorted(uids)
    for r in results.values():
        _assert_bitwise(r, ref)


def test_service_http_metrics_endpoint():
    A, B = _pair(34)
    svc = SpgemmService(CFG, arena=Arena())
    svc.call(A, B, tenant="acme")
    svc.call(A, B, tenant="zeta")
    server = svc.serve_http()
    try:
        body = urllib.request.urlopen(server.url, timeout=10).read().decode()
        health = urllib.request.urlopen(
            server.url.replace("/metrics", "/healthz"), timeout=10).read()
    finally:
        svc.close()
    assert health == b"ok\n"
    assert 'opsparse_service_requests_total{tenant="acme"} 1' in body
    assert 'opsparse_service_requests_total{tenant="zeta"} 1' in body
    assert 'opsparse_engine_requests_total{tenant="acme"}' in body
    assert "opsparse_service_tenants 2" in body
    # Valid exposition shape: one TYPE header per metric name.
    for name in ("opsparse_service_requests_total",
                 "opsparse_engine_requests_total"):
        assert body.count(f"# TYPE {name} ") == 1


# ---------------------------------------------------------------------------
# ServingEngine structured rejection (serve/engine.py satellite) is in
# tests/test_serving.py next to the other LM-serving tests.
# ---------------------------------------------------------------------------

def test_service_result_ok_property():
    assert ServiceResult(status="ok", tenant="t").ok
    assert not ServiceResult(status="timeout", tenant="t").ok
