"""Fused symbolic->numeric hash kernels + multi-row VMEM packing (ISSUE 4).

Covers the tentpole guarantees: the one-build fused pipeline is bitwise-
identical to the two-pass oracle (nnz / structure / values, both probe
disciplines), row packing is a pure layout change (bitwise parity across
rung boundaries), fusion strictly reduces per-row table transactions
(fused <= symbolic + numeric, measured not asserted), and the engine's
fused steady state serves repeat shapes with zero retraces.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SpgemmConfig, bin_rows_for_ladder, next_bucket,
                        nprod_into_rpt, random_csr, esc)
from repro.core.analysis import exclusive_sum_in_place
from repro.core.binning_ranges import (make_ladder, numeric_ladder,
                                       rows_per_block_of, symbolic_ladder)
from repro.engine import SpgemmEngine, total_traces
from repro.kernels import default_interpret, resolve_interpret, spgemm_hash


def _pair(seed, m, k, n, da, db, dist="uniform"):
    A = random_csr(jax.random.PRNGKey(seed), m, k, avg_nnz_per_row=da,
                   distribution=dist)
    B = random_csr(jax.random.PRNGKey(seed + 100), k, n, avg_nnz_per_row=db,
                   distribution=dist)
    return A, B


def _two_pass(A, B, sym_lad, num_lad, single_access=True):
    """The two-pass oracle: symbolic -> rpt -> numeric."""
    m = A.nrows
    nprod = nprod_into_rpt(A, B)[:m]
    sym_bn = bin_rows_for_ladder(nprod, sym_lad)
    nnz_buf = spgemm_hash.symbolic_binned(A, B, sym_bn, sym_lad,
                                          single_access=single_access)
    num_bn = bin_rows_for_ladder(nnz_buf[:m], num_lad)
    cap = next_bucket(max(int(nnz_buf[:m].sum()), 1))
    rpt = exclusive_sum_in_place(nnz_buf)
    C = spgemm_hash.numeric_binned(A, B, rpt, num_bn, num_lad,
                                   nnz_capacity=cap,
                                   single_access=single_access)
    return C, cap, sym_bn


def _fused(A, B, sym_lad, cap, sym_bn, *, single_access=True, packed=False):
    return spgemm_hash.fused_binned(A, B, sym_bn, sym_lad, nnz_capacity=cap,
                                    single_access=single_access,
                                    row_packing=packed)


@pytest.mark.parametrize("single_access", [True, False])
def test_fused_vs_two_pass_bitwise_parity(single_access):
    """One table build must reproduce the double build EXACTLY: same nnz,
    same sorted structure, bitwise-equal values (the per-column accumulation
    order — A-entry major, B-entry minor — is identical in both kernels)."""
    A, B = _pair(7, 72, 96, 80, 5.0, 4.0)
    sym_lad, num_lad = symbolic_ladder(1.2), numeric_ladder(2.0)
    C2, cap, sym_bn = _two_pass(A, B, sym_lad, num_lad, single_access)
    C1 = _fused(A, B, sym_lad, cap, sym_bn, single_access=single_access)
    nnz = int(C2.rpt[-1])
    assert nnz > 0
    np.testing.assert_array_equal(np.asarray(C1.rpt), np.asarray(C2.rpt))
    np.testing.assert_array_equal(np.asarray(C1.col)[:nnz],
                                  np.asarray(C2.col)[:nnz])
    np.testing.assert_array_equal(np.asarray(C1.val)[:nnz],
                                  np.asarray(C2.val)[:nnz])


def test_fused_multi_rung_with_fallback_matches_oracle():
    """Tiny ladders force several rungs AND the ESC fallback rung through
    the fused path; nnz/structure stay exact against the dense oracle
    (values allclose: ESC fallback rows may sum in a different order)."""
    m = 96
    A, B = _pair(9, m, 200, 150, 10.0, 8.0, dist="powerlaw")
    sym_lad = make_ladder((32, 64, 128), 1.2, (32, 64, 128))
    nprod = nprod_into_rpt(A, B)[:m]
    sym_bn = bin_rows_for_ladder(nprod, sym_lad)
    sizes = np.asarray(sym_bn.bin_size)
    assert (sizes[:-1] > 0).sum() >= 2 and sizes[-1] > 0  # rungs + fallback
    nnz_buf = esc.symbolic(A, B, prod_capacity=next_bucket(8192))
    cap = next_bucket(int(nnz_buf.sum()))
    C = spgemm_hash.fused_binned(A, B, sym_bn, sym_lad, nnz_capacity=cap)
    ref = np.asarray(A.to_dense()) @ np.asarray(B.to_dense())
    np.testing.assert_array_equal(
        np.asarray(C.rpt[1:]) - np.asarray(C.rpt[:-1]),
        (ref != 0).sum(axis=1))
    np.testing.assert_allclose(np.asarray(C.to_dense()), ref,
                               rtol=1e-5, atol=1e-5)
    rptn, coln = np.asarray(C.rpt), np.asarray(C.col)
    for i in range(m):
        seg = coln[rptn[i]:rptn[i + 1]]
        assert (np.diff(seg) > 0).all()    # rows sorted by column


def test_packed_vs_unpacked_bitwise_parity_across_rungs():
    """Row packing is a pure occupancy/layout change: sub-tables keep the
    per-row table size, so probe sequences — and therefore nnz, structure,
    values, and transaction counts — are bitwise-identical."""
    m = 96
    A, B = _pair(11, m, 160, 120, 8.0, 6.0, dist="powerlaw")
    sym_lad = make_ladder((32, 64, 128, 256), 1.2, (32, 64, 128, 256))
    assert sym_lad.rows_per_block[0] > 1     # packing actually engages
    nprod = nprod_into_rpt(A, B)[:m]
    sym_bn = bin_rows_for_ladder(nprod, sym_lad)
    assert (np.asarray(sym_bn.bin_size)[:-1] > 0).sum() >= 2
    cap = next_bucket(int(esc.symbolic(A, B,
                                       prod_capacity=next_bucket(8192)).sum()))
    Cu, acc_u = spgemm_hash.fused_binned(A, B, sym_bn, sym_lad,
                                         nnz_capacity=cap, row_packing=False,
                                         collect_accesses=True)
    Cp, acc_p = spgemm_hash.fused_binned(A, B, sym_bn, sym_lad,
                                         nnz_capacity=cap, row_packing=True,
                                         collect_accesses=True)
    np.testing.assert_array_equal(np.asarray(Cu.rpt), np.asarray(Cp.rpt))
    np.testing.assert_array_equal(np.asarray(Cu.col), np.asarray(Cp.col))
    np.testing.assert_array_equal(np.asarray(Cu.val), np.asarray(Cp.val))
    assert int(acc_u) == int(acc_p)


def test_packed_geometry_and_ladder_rows_per_block():
    """Pack counts are pow-2, tile-bounded, and 1 once a table fills the
    minimum (8, 128) int32 tile."""
    assert rows_per_block_of(32) == 32
    assert rows_per_block_of(512) == 2
    assert rows_per_block_of(1024) == 1
    assert rows_per_block_of(24576) == 1
    lad = symbolic_ladder(1.2)
    assert lad.rows_per_block == tuple(
        rows_per_block_of(t) for t in lad.table_sizes)
    for t, p in zip(lad.table_sizes, lad.rows_per_block):
        t_rows, stride = spgemm_hash._packed_geom(t, p)
        assert stride >= t and t_rows * 128 == p * stride


def test_fused_accesses_leq_two_pass_per_row():
    """Access-count regression (the Fig.-9 counters, per row): building the
    table once must cost no more transactions than building it twice —
    fused <= symbolic + numeric for EVERY row."""
    m = 80
    A, B = _pair(13, m, 100, 90, 6.0, 5.0)
    sym_lad, num_lad = symbolic_ladder(1.2), numeric_ladder(2.0)
    nprod = nprod_into_rpt(A, B)[:m]
    sym_bn = bin_rows_for_ladder(nprod, sym_lad)
    nnz_buf = spgemm_hash.symbolic_binned(A, B, sym_bn, sym_lad)
    num_bn = bin_rows_for_ladder(nnz_buf[:m], num_lad)

    def per_row_accesses(binning, ladder, call):
        out = {}
        sizes = np.asarray(binning.bin_size)
        for b, t_size in enumerate(ladder.table_sizes):
            if not sizes[b]:
                continue
            rows_cap = next_bucket(int(sizes[b]), minimum=8)
            rows, count = binning.rows_of_bin(b, rows_cap)
            acc = call(rows, count.reshape(1), t_size, rows_cap)
            rr, aa = np.asarray(rows), np.asarray(acc)
            for i in range(int(sizes[b])):
                out[int(rr[i])] = int(aa[i])
        return out

    sym_acc = per_row_accesses(
        sym_bn, sym_lad,
        lambda rows, cnt, t, cap: spgemm_hash.symbolic_bin_call(
            rows, cnt, A.rpt, A.col, B.rpt, B.col,
            t_size=t, rows_cap=cap, single_access=True)[1])
    num_acc = per_row_accesses(
        num_bn, num_lad,
        lambda rows, cnt, t, cap: spgemm_hash.numeric_bin_call(
            rows, cnt, A.rpt, A.col, A.val, B.rpt, B.col, B.val,
            t_size=t, rows_cap=cap, single_access=True)[2])
    fused_acc = per_row_accesses(
        sym_bn, sym_lad,
        lambda rows, cnt, t, cap: spgemm_hash.fused_bin_call(
            rows, cnt, A.rpt, A.col, A.val, B.rpt, B.col, B.val,
            t_size=t, rows_cap=cap, single_access=True)[3])

    assert set(fused_acc) == set(sym_acc)
    checked = 0
    for r, f in fused_acc.items():
        if r in num_acc:               # row served by kernels in both phases
            assert f <= sym_acc[r] + num_acc[r], r
            checked += 1
    assert checked >= m // 2
    total_two = sum(sym_acc.values()) + sum(num_acc.values())
    total_fused = sum(fused_acc.values())
    assert total_fused * 3 <= total_two * 2    # >= 1.5x reduction overall


def test_host_schedule_pack_alignment():
    """``host_schedule(packs=...)`` floors populated rungs at their pack
    so packed kernels always get whole grid steps."""
    m = 96
    A, B = _pair(17, m, 160, 120, 8.0, 6.0, dist="powerlaw")
    lad = make_ladder((32, 64, 128), 1.2, (32, 64, 128))
    bn = bin_rows_for_ladder(nprod_into_rpt(A, B)[:m], lad)
    buckets, _ = spgemm_hash.host_schedule(A, B, bn, lad,
                                           packs=lad.rows_per_block)
    sizes = np.asarray(bn.bin_size)
    for b, (s, cap) in enumerate(zip(sizes[:-1], buckets[:-1])):
        if not s:
            assert cap == 0
            continue
        pack = lad.rows_per_block[b]
        assert cap >= max(int(s), pack) and cap % pack == 0


@pytest.mark.parametrize("row_packing", [False, True])
def test_engine_fused_steady_state_zero_retraces(row_packing):
    """The fused executable serves repeat shapes with zero retraces and
    stays bitwise-identical to the two-pass engine path."""
    cfg = SpgemmConfig(method="hash", fuse_numeric=True,
                       row_packing=row_packing)
    engine = SpgemmEngine(cfg)
    # Explicit two-pass oracle: fuse_numeric became the hash DEFAULT, so
    # a bare hash config would compare the fused executable with itself.
    oracle = SpgemmEngine(SpgemmConfig(method="hash", fuse_numeric=False))
    pairs = [_pair(31 + s, 48, 64, 56, 4.0, 3.0) for s in range(5)]
    cap_a = next_bucket(max(A.capacity for A, _ in pairs))
    cap_b = next_bucket(max(B.capacity for _, B in pairs))
    pairs = [(A.with_capacity(cap_a), B.with_capacity(cap_b))
             for A, B in pairs]

    baseline = None
    for i, (A, B) in enumerate(pairs):
        res = engine.execute(A, B)
        ref = oracle.execute(A, B)
        nnz = ref.total_nnz
        assert res.total_nnz == nnz
        # Steady-state fused results keep the cold-call telemetry shape.
        assert res.sym_binning is not None and res.num_binning is not None
        np.testing.assert_array_equal(np.asarray(res.C.rpt),
                                      np.asarray(ref.C.rpt))
        np.testing.assert_array_equal(np.asarray(res.C.col)[:nnz],
                                      np.asarray(ref.C.col)[:nnz])
        np.testing.assert_array_equal(np.asarray(res.C.val)[:nnz],
                                      np.asarray(ref.C.val)[:nnz])
        if i == 1:
            baseline = total_traces()   # cold + first fused/oracle traces
    assert total_traces() == baseline   # zero retraces on the tail
    entry = next(e for _, e in engine.cache.items())
    assert entry.stats.hot_calls >= 3
    assert entry.plan.config.fuse_numeric


def test_engine_fused_overflow_grows_and_recovers():
    """A same-signature request outgrowing the fused plan's schedule must
    fall back to the steps oracle, grow the plan, and stay correct."""
    cfg = SpgemmConfig(method="hash", fuse_numeric=True, row_packing=True)
    engine = SpgemmEngine(cfg)
    small = _pair(41, 64, 96, 72, 2.0, 2.0)
    big = _pair(43, 64, 96, 72, 12.0, 9.0, dist="powerlaw")
    cap_a = next_bucket(max(small[0].capacity, big[0].capacity))
    cap_b = next_bucket(max(small[1].capacity, big[1].capacity))
    for A, B in (small, big, small):
        A, B = A.with_capacity(cap_a), B.with_capacity(cap_b)
        res = engine.execute(A, B)
        ref = np.asarray(A.to_dense()) @ np.asarray(B.to_dense())
        np.testing.assert_allclose(np.asarray(res.C.to_dense()), ref,
                                   rtol=1e-4, atol=1e-4)


def _bitwise_same(C1, C2, nnz):
    np.testing.assert_array_equal(np.asarray(C1.rpt), np.asarray(C2.rpt))
    np.testing.assert_array_equal(np.asarray(C1.col)[:nnz],
                                  np.asarray(C2.col)[:nnz])
    np.testing.assert_array_equal(np.asarray(C1.val)[:nnz],
                                  np.asarray(C2.val)[:nnz])


@pytest.mark.parametrize("row_packing", [False, True])
def test_fused_degenerate_all_zero_rows(row_packing):
    """All-zero rows under the fused/packed path: empty rows become empty
    sub-tables (nnz 0, no scatter), bitwise-mirroring the two-pass
    oracle.  Regression for the packed sub-table offsets of empty rows."""
    from repro.core import CSR
    m = 48
    d = np.zeros((m, 40), np.float32)
    rng = np.random.RandomState(0)
    occupied = rng.choice(m, size=m // 3, replace=False)
    d[occupied, :5] = rng.rand(len(occupied), 5).astype(np.float32) + 0.5
    A = CSR.from_dense(d)
    B = random_csr(jax.random.PRNGKey(3), 40, 36, avg_nnz_per_row=4.0)
    sym_lad, num_lad = symbolic_ladder(1.2), numeric_ladder(2.0)
    C2, cap, sym_bn = _two_pass(A, B, sym_lad, num_lad)
    C1 = spgemm_hash.fused_binned(A, B, sym_bn, sym_lad, nnz_capacity=cap,
                                  row_packing=row_packing)
    nnz = int(C2.rpt[-1])
    assert nnz > 0
    _bitwise_same(C1, C2, nnz)
    # Zero rows really are zero in the result.
    rpt = np.asarray(C1.rpt)
    empty = np.setdiff1d(np.arange(m), occupied)
    assert (rpt[empty + 1] == rpt[empty]).all()


@pytest.mark.parametrize("zero_side", ["A", "B", "both"])
def test_fused_degenerate_nnz_zero_matrices(zero_side):
    """nnz=0 operands through the fused/packed pipeline: the result is the
    empty CSR, bitwise-mirroring the two-pass oracle (empty rows' packed
    sub-table offsets must not scatter anything)."""
    from repro.core import CSR
    m, k, n = 32, 28, 24
    A = (CSR.from_dense(np.zeros((m, k), np.float32)) if zero_side != "B"
         else random_csr(jax.random.PRNGKey(5), m, k, avg_nnz_per_row=3.0))
    B = (CSR.from_dense(np.zeros((k, n), np.float32)) if zero_side != "A"
         else random_csr(jax.random.PRNGKey(6), k, n, avg_nnz_per_row=3.0))
    sym_lad, num_lad = symbolic_ladder(1.2), numeric_ladder(2.0)
    C2, cap, sym_bn = _two_pass(A, B, sym_lad, num_lad)
    C1 = spgemm_hash.fused_binned(A, B, sym_bn, sym_lad, nnz_capacity=cap,
                                  row_packing=True)
    assert int(C1.rpt[-1]) == 0
    _bitwise_same(C1, C2, 0)
    assert not np.asarray(C1.to_dense()).any()


def test_engine_fused_packed_degenerate_stream():
    """The engine's fused+packed steady state on degenerate inputs: an
    all-zero A and a zero-row A share the signature bucket with a dense
    one; every result mirrors the two-pass engine bitwise."""
    from repro.core import CSR
    m, k, n = 32, 28, 24
    cfg = SpgemmConfig(method="hash", fuse_numeric=True, row_packing=True)
    engine = SpgemmEngine(cfg)
    oracle = SpgemmEngine(SpgemmConfig(method="hash", fuse_numeric=False))
    dense, B = _pair(51, m, k, n, 3.0, 3.0)
    d_half = np.asarray(dense.to_dense()).copy()
    d_half[m // 2:] = 0.0                # bottom half all-zero rows
    cap_a = next_bucket(dense.capacity)
    variants = [dense.with_capacity(cap_a),
                CSR.from_dense(d_half).with_capacity(cap_a),
                CSR.from_dense(np.zeros((m, k), np.float32))
                .with_capacity(cap_a)]
    for A in variants * 2:               # cold + hot coverage per variant
        res = engine.execute(A, B)
        ref = oracle.execute(A, B)
        assert res.total_nnz == ref.total_nnz
        _bitwise_same(res.C, ref.C, ref.total_nnz)


def test_interpret_auto_detect():
    """interpret=None resolves per-backend (interpreted off-TPU), and the
    config default no longer hardwires interpret mode."""
    assert SpgemmConfig().interpret is None
    assert resolve_interpret(None) == default_interpret()
    assert default_interpret() == (jax.default_backend() != "tpu")
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
