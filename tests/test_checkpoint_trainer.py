"""Fault-tolerance substrate: checkpoint round-trip, elastic resharding,
NaN rollback, preemption, straggler accounting, data-stream resumption."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.models.model import Model
from repro.optim import AdamWConfig
from repro.train import checkpoint as ckpt
from repro.train.trainer import Trainer, TrainerConfig
from repro.launch.steps import init_train_state, make_train_step


def _tiny_setup(tmp_path, total_steps=12, ckpt_every=4):
    cfg = get_arch("internlm2-1.8b").reduced().replace(
        num_layers=2, d_model=32, d_ff=64, vocab_size=64, num_heads=2,
        num_kv_heads=2, dtype="float32")
    model = Model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    data = SyntheticTokenStream(DataConfig(vocab_size=64, seq_len=16,
                                           global_batch=4))
    tc = TrainerConfig(total_steps=total_steps, ckpt_every=ckpt_every,
                       ckpt_dir=str(tmp_path / "ck"), log_every=100)
    return model, state, step_fn, data, tc


def test_checkpoint_round_trip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    ckpt.save(tmp_path, 3, tree, extra={"train_step": 3, "data_step": 7})
    restored, extra = ckpt.restore(tmp_path, tree)
    assert extra["train_step"] == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_atomicity_and_gc(tmp_path):
    tree = {"x": jnp.zeros((3,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, tree, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_0000004", "step_0000005"]
    assert not list(tmp_path.glob("tmp_*"))
    assert ckpt.latest_step(tmp_path) == 5


def test_checkpoint_elastic_resharding(tmp_path):
    """Restore under a different sharding (elastic restart path)."""
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt.save(tmp_path, 1, tree)
    # axis_types/AxisType only exists on newer JAX; default axis types are
    # what we want on every version.
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))
    restored, _ = ckpt.restore(tmp_path, tree, shardings={"w": sh})
    assert restored["w"].sharding == sh
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_trainer_end_to_end_and_resume(tmp_path):
    model, state, step_fn, data, tc = _tiny_setup(tmp_path)
    tr = Trainer(step_fn, data, tc)
    _, step = tr.fit(state, resume=False)
    assert step == tc.total_steps
    losses = [m["loss"] for m in tr.metrics_history]
    assert all(np.isfinite(l) for l in losses)

    # resume from checkpoint: a fresh trainer continues, not restarts
    tc2 = TrainerConfig(**{**tc.__dict__, "total_steps": 16})
    data2 = SyntheticTokenStream(data.cfg)
    tr2 = Trainer(jax.jit(step_fn), data2, tc2)
    model2 = Model  # noqa
    state2, step2 = tr2.fit(state, resume=True)
    assert step2 == 16
    assert tr2.metrics_history[0]["step"] == 13   # continued, not restarted


def test_trainer_nan_rollback(tmp_path):
    model, state, step_fn, data, tc = _tiny_setup(tmp_path, total_steps=10,
                                                  ckpt_every=3)
    calls = {"n": 0}

    def poisoned_step(state, batch):
        calls["n"] += 1
        new_state, metrics = step_fn(state, batch)
        if calls["n"] == 5:       # poison exactly one step
            metrics = dict(metrics)
            metrics["loss"] = jnp.float32(jnp.nan)
        return new_state, metrics

    tr = Trainer(poisoned_step, data, tc)
    _, step = tr.fit(state, resume=False)
    assert step == 10
    assert tr.rollbacks == 1
    assert all(np.isfinite(m["loss"]) for m in tr.metrics_history)


def test_trainer_preemption_checkpoints(tmp_path):
    model, state, step_fn, data, tc = _tiny_setup(tmp_path, total_steps=50,
                                                  ckpt_every=100)

    tr = Trainer(step_fn, data, tc)
    orig = tr.step_fn

    def slow_then_preempt(state, batch):
        out = orig(state, batch)
        if len(tr.metrics_history) >= 4:
            tr.preempted = True       # simulate SIGTERM delivery
        return out

    tr.step_fn = slow_then_preempt
    _, step = tr.fit(state, resume=False)
    assert step < 50
    assert ckpt.latest_step(tc.ckpt_dir) == step  # checkpointed on exit


def test_trainer_straggler_detection(tmp_path):
    model, state, step_fn, data, tc = _tiny_setup(tmp_path, total_steps=20)
    tc.straggler_warmup = 3
    tc.straggler_factor = 2.0
    events = []

    def slow_step(state, batch):
        if len(events) == 0 and data.step == 15:
            time.sleep(0.5)
        return step_fn(state, batch)

    tr = Trainer(slow_step, data, tc,
                 straggler_cb=lambda s, t: events.append((s, t)))
    tr.fit(state, resume=False)
    assert tr.straggler_events >= 1


def test_data_stream_determinism_and_resume():
    cfg = DataConfig(vocab_size=97, seq_len=256, global_batch=8, seed=5)
    s1 = SyntheticTokenStream(cfg)
    batches = [s1.next_batch()["tokens"] for _ in range(4)]
    s2 = SyntheticTokenStream.from_state(cfg, {"step": 2, "seed": 5})
    np.testing.assert_array_equal(np.asarray(s2.next_batch()["tokens"]),
                                  np.asarray(batches[2]))
    # learnable structure: consecutive tokens obey the recurrence at the
    # (1-noise)^2 ~ 0.81 rate
    t = np.asarray(batches[0])
    hits = (t[:, 1:] == (t[:, :-1] * cfg.mult + cfg.add) % cfg.vocab_size)
    assert 0.7 < hits.mean() < 0.95
