"""Sweeps for the binning-histogram and BSR-SpMM Pallas kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bin_rows, symbolic_ladder
from repro.kernels import ref as kref
from repro.kernels.binning_pallas import binning_histogram
from repro.kernels.bsr_spmm import bsr_spmm


@pytest.mark.parametrize("m", [7, 256, 1000, 4096])
@pytest.mark.parametrize("block", [128, 1024])
def test_binning_histogram_matches_reference(m, block):
    lad = symbolic_ladder(1.2)
    sizes = jax.random.randint(jax.random.PRNGKey(m), (m,), 0, 30000)
    hist, mx = binning_histogram(sizes, upper=lad.upper,
                                 num_bins=lad.num_bins, block=block)
    ref = bin_rows(sizes, upper=lad.upper, num_bins=lad.num_bins)
    np.testing.assert_array_equal(np.asarray(hist),
                                  np.asarray(ref.bin_size))
    assert int(mx) == int(ref.max_size)


def _random_bcsr(key, nbr, nbc, bm, bk, density=0.3):
    rng = np.random.default_rng(int(jax.random.bits(key, dtype=jnp.uint32)))
    mask = rng.random((nbr, nbc)) < density
    mask[0, 0] = True                      # at least one block
    rows, cols = np.nonzero(mask)
    blocks = rng.standard_normal((len(rows), bm, bk)).astype(np.float32)
    return (jnp.asarray(rows, jnp.int32), jnp.asarray(cols, jnp.int32),
            jnp.asarray(blocks))


@pytest.mark.parametrize("shape", [(3, 4, 8, 16, 32), (5, 2, 16, 8, 8),
                                   (2, 2, 32, 32, 64)])
def test_bsr_spmm_matches_reference(shape):
    nbr, nbc, bm, bk, n = shape
    rows, cols, blocks = _random_bcsr(jax.random.PRNGKey(0), nbr, nbc,
                                      bm, bk)
    dense = jax.random.normal(jax.random.PRNGKey(1), (nbc * bk, n))
    got = bsr_spmm(rows, cols, blocks, dense, n_block_rows=nbr)
    ref = kref.bsr_spmm_ref(rows, cols, blocks, dense, nrows_blocks=nbr,
                            block_shape=(bm, bk))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_bsr_spmm_with_padding_blocks():
    """Padding entries (repeat last row, zero block) contribute nothing."""
    rows = jnp.asarray([0, 0, 1, 1, 1], jnp.int32)
    cols = jnp.asarray([0, 1, 1, 0, 0], jnp.int32)
    blocks = jnp.stack([jnp.eye(8)] * 3 + [jnp.eye(8)] +
                       [jnp.zeros((8, 8))])
    dense = jax.random.normal(jax.random.PRNGKey(2), (16, 24))
    got = bsr_spmm(rows, cols, blocks, dense, n_block_rows=2)
    ref = kref.bsr_spmm_ref(rows, cols, blocks, dense, nrows_blocks=2,
                            block_shape=(8, 8))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)
