"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (per brief).

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models.model import Model
from repro.models.param import param_count


def _batch_for(cfg, b=2, s=16, key=0):
    k = jax.random.PRNGKey(key)
    if cfg.family == "encoder":
        return {
            "features": jax.random.normal(k, (b, s, cfg.d_model),
                                          jnp.float32),
            "labels": jax.random.randint(k, (b, s), 0, cfg.vocab_size),
        }
    batch = {"tokens": jax.random.randint(k, (b, s + 1), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            k, (b, cfg.vision_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_train_step(arch):
    cfg = get_arch(arch).reduced().replace(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)), (arch, float(loss))
    # gradient finiteness across the whole tree
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), arch
    # at least one grad is nonzero (model is actually wired to the loss)
    assert any(float(jnp.abs(g).max()) > 0 for g in flat), arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_prefill_shapes(arch):
    cfg = get_arch(arch).reduced().replace(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 16
    batch = _batch_for(cfg, b, s)
    if cfg.family == "encoder":
        logits, caches = model.prefill(params, batch)
        assert logits.shape == (b, s, cfg.vocab_size)
        assert caches is None
        assert np.isfinite(np.asarray(logits)).all()
        return
    prefill_batch = {"tokens": batch["tokens"][:, :s]}
    if "vision" in batch:
        prefill_batch["vision"] = batch["vision"]
    logits, caches = model.prefill(params, prefill_batch, kv_cache_len=s + 4)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert caches is not None


@pytest.mark.parametrize("arch", [a for a in sorted(ARCHS)
                                  if ARCHS[a].family != "encoder"])
def test_reduced_decode_step(arch):
    """prefill(s) + decode(1) must equal prefill(s+1) logits."""
    cfg = get_arch(arch).reduced().replace(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, s + 1), 0,
                                cfg.vocab_size)
    base = {"vision": jax.random.normal(
        jax.random.PRNGKey(4), (b, cfg.vision_tokens, cfg.d_model),
        jnp.float32)} if cfg.family == "vlm" else {}

    full_logits, _ = model.prefill(
        params, {"tokens": tokens, **base}, kv_cache_len=s + 1)
    _, caches = model.prefill(
        params, {"tokens": tokens[:, :s], **base}, kv_cache_len=s + 1)
    step_logits, _ = model.decode_step(params, tokens[:, s:s + 1], caches,
                                       jnp.int32(s))
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_param_counts_full_configs():
    """Full-config parameter counts are in the right ballpark (order of
    magnitude check on the exact assigned configs — catches config typos)."""
    expected = {
        "falcon-mamba-7b": (6e9, 9e9),
        "hubert-xlarge": (0.7e9, 1.3e9),
        "qwen3-1.7b": (1.3e9, 2.5e9),
        "minitron-4b": (3.5e9, 6e9),
        "internlm2-1.8b": (1.4e9, 2.5e9),
        "codeqwen1.5-7b": (6e9, 8.5e9),
        "zamba2-1.2b": (0.9e9, 1.7e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "llama-3.2-vision-90b": (80e9, 100e9),
    }
    for name, (lo, hi) in expected.items():
        n = param_count(Model(get_arch(name)).param_specs())
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
