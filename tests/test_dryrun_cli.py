"""Integration: the multi-pod dry-run CLI compiles a real cell end-to-end
(subprocess — the 512-device override must precede jax init)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_dryrun_cli_single_cell(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "qwen3-1.7b", "--shape", "decode_32k", "--mesh", "single"],
        capture_output=True, text=True, timeout=420,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp", "JAX_PLATFORMS": "cpu"},
        cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "[ok] qwen3-1.7b|decode_32k|16x16" in proc.stdout
    art = json.loads(
        (REPO / "results/dryrun/qwen3-1.7b_decode_32k_16-16.json")
        .read_text())
    assert art["chips"] == 256
    assert art["roofline"]["flops"] > 0
    assert art["memory_analysis"]["temp_size_in_bytes"] > 0
