#!/usr/bin/env bash
# Tier-1 gate + engine perf wiring, run on every PR.
#   ./scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1 tests =="
python -m pytest -x -q

echo
echo "== opslint gate (static analysis: fail on NEW findings vs baseline) =="
# AST-only — no JAX execution, so it runs ahead of the bench gates.
# Rules: trace-safety (TRC), donation discipline (DON), lock order /
# guarded-by races (LCK), host-int width (INT), kernel budgets (KRN).
# `--fail-on-new` diffs against the checked-in opslint_baseline.json;
# refresh it with `scripts/opslint --write-baseline opslint_baseline.json`
# only after triaging (fix true positives, suppress documented FPs).
python -m repro.analysis_static src/repro --fail-on-new \
    --baseline opslint_baseline.json --format json

echo
echo "== engine smoke benchmark (plan-cache effectiveness) =="
python benchmarks/bench_engine.py --smoke

echo
echo "== engine smoke benchmark (hash method: zero-retrace steady state) =="
python benchmarks/bench_engine.py --smoke --method hash

echo
echo "== engine smoke benchmark (adaptive policy: auto shards + tracked headroom) =="
python benchmarks/bench_engine.py --smoke --method hash --adaptive

echo
echo "== engine smoke benchmark (fused hash: one-build tables + row packing) =="
python benchmarks/bench_engine.py --smoke --method hash --fused

echo
echo "== engine smoke benchmark (sharded: partition parity + plan reuse) =="
python benchmarks/bench_engine.py --smoke --shards 2

echo
echo "== arena gate (K shape buckets under a governor cap: peak bytes, parity) =="
# 8 distinct shape-bucket plans share one workspace arena with the
# governor capped at 0.6x the per-plan-buffer baseline; gates peak
# workspace bytes <= cap (and strictly below the baseline), zero
# retraces after warmup, and bitwise parity vs an uncapped engine.
python benchmarks/bench_engine.py --smoke --arena

echo
echo "== estimate gate (sampled cold planning: >=3x sizing, bitwise parity) =="
# plan_mode="estimate" stream first (cold — its sizing is a host-side
# sampled estimate, no kernel compiles), exact-planning baseline second
# on a fresh engine in the same process (ordering biases AGAINST the
# gate).  Gates: the estimator beats the exact symbolic sizing pass
# >=3x, the full first call is no slower, zero post-warmup retraces,
# steady state no worse, and bitwise result parity on every request.
python benchmarks/bench_engine.py --smoke --estimate --method hash

echo
echo "== telemetry gate (traced smoke: schema-valid spans, <5% overhead) =="
# The trace is schema-validated in-process (validate_chrome_trace) and
# must contain the full nested span pipeline including the sharded
# fan-out.  The <5% overhead gate is a same-process A/B (steady tail
# re-run with tracing on vs off on the same engine) so ambient machine
# load between separate CI steps can't flake it; the untraced --shards 2
# smoke above still records the cross-run steady_min_ms baseline printed
# for the trajectory.
python benchmarks/bench_engine.py --smoke --shards 2 \
    --trace /tmp/opsparse_smoke_trace.json

echo
echo "== chaos gate (serving front-end: seeded faults, zero failures, parity) =="
# A mixed-tenant stream runs fault-free, then again under a seeded
# FaultPlan (lease denials + verify overflows, plus a deterministic
# double denial that forces the service retry ladder).  Gates: zero
# failed well-formed requests, every chaos result bitwise identical to
# its fault-free twin, bounded p99 inflation, a poisoned request errors
# WITHOUT retrying, a stalled request under deadline returns a
# structured timeout, and the per-tenant counters appear on a live
# /metrics scrape.
python benchmarks/bench_engine.py --smoke --serve
