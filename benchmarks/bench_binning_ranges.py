"""Figs. 10/11 reproduction: binning-range selection sweep.

The paper sweeps sym {1x, 1.2x, 1.5x} and num {1x, 1.5x, 2x, 3x} range
multipliers and finds sym_1.2x / num_2x best on average — the collision-
rate vs occupancy trade-off of §4.3.  We sweep the same grid and report
the exact per-row table-transaction counts (collision probes included)
from the instrumented Pallas kernels, plus the implied mean occupancy of
the chosen tables.  Fewer transactions at higher multiplier = the paper's
collision effect; larger tables at higher multiplier = its occupancy cost
(on GPU: fewer resident blocks; on TPU: more VMEM per core).
"""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from repro.core import (NUMERIC_SWEEP, SYMBOLIC_SWEEP, bin_rows_for_ladder,
                        esc, next_bucket, nprod_into_rpt, numeric_ladder,
                        random_csr, symbolic_ladder)
from repro.core.analysis import exclusive_sum_in_place
from repro.kernels import spgemm_hash


def _occupancy(binning, ladder, sizes):
    """Mean fill fraction of the hash tables actually used."""
    sizes = np.asarray(sizes)
    bin_of = np.asarray(binning.bin_of_row)
    occ = []
    for b, t in enumerate(ladder.table_sizes):
        members = sizes[bin_of == b]
        if len(members):
            occ.append(members.mean() / t)
    return float(np.mean(occ)) if occ else 0.0


def run() -> List[str]:
    rows = []
    A = random_csr(jax.random.PRNGKey(5), 256, 1024, avg_nnz_per_row=10.0,
                   distribution="powerlaw")
    B = random_csr(jax.random.PRNGKey(6), 1024, 512, avg_nnz_per_row=8.0,
                   distribution="powerlaw")
    m = A.nrows
    nprod = nprod_into_rpt(A, B)[:m]

    for mult in SYMBOLIC_SWEEP:
        lad = symbolic_ladder(mult)
        bn = bin_rows_for_ladder(nprod, lad)
        _, acc = spgemm_hash.symbolic_binned(
            A, B, bn, lad, prod_capacity=1, single_access=True,
            collect_accesses=True)
        rows.append(
            f"bench_binning_ranges/sym_{mult}x,{int(acc)},"
            f"accesses={int(acc)};occupancy={_occupancy(bn, lad, nprod):.3f}")
        print(rows[-1], flush=True)

    nnz_buf = esc.symbolic(A, B, prod_capacity=next_bucket(int(nprod.sum())))
    rpt = exclusive_sum_in_place(nnz_buf)
    cap = next_bucket(int(rpt[-1]))
    for mult in NUMERIC_SWEEP:
        lad = numeric_ladder(mult)
        bn = bin_rows_for_ladder(nnz_buf[:m], lad)
        _, acc = spgemm_hash.numeric_binned(
            A, B, rpt, bn, lad, prod_capacity=1, nnz_capacity=cap,
            single_access=True, collect_accesses=True)
        rows.append(
            f"bench_binning_ranges/num_{mult}x,{int(acc)},"
            f"accesses={int(acc)};"
            f"occupancy={_occupancy(bn, lad, nnz_buf[:m]):.3f}")
        print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
