"""§6.3.4/6.3.5 reproduction: dispatch-order load balance + alloc overlap.

  * overlap  — the paper overlaps cudaMalloc with kernel execution; the
    JAX analog is ASYNC DISPATCH through the engine: ``submit`` queues N
    independent SpGEMMs and ``drain`` keeps a window of dispatches in
    flight (host-side planning, arena leasing, and verify syncs overlap
    device execution), vs a serialized loop that blocks after every
    request.  The delta is the host time hidden behind device work.
  * order    — the paper launches large-row kernels first (§5.5).  Our
    hash path dispatches bins largest-first inside the executable, so
    the measured pipeline inherits that ordering for free.

Since ISSUE 7 this bench drives :class:`repro.engine.SpgemmEngine` (the
same arena-leased steady-state path serving traffic uses), not the
one-shot ``core.spgemm`` — so the pipelined side also measures the
workspace-arena checkout/return riding the dispatch/finalize split.
"""
from __future__ import annotations

import time
from typing import List

import jax

from repro.core import SpgemmConfig
from repro.engine import Arena, SpgemmEngine

from .common import REPS
from .matrices import generate, NORMAL


def run() -> List[str]:
    rows = []
    spec = NORMAL[7]                      # cage12 analog (mid-size)
    A = generate(spec)
    cfg = SpgemmConfig(method="esc")
    engine = SpgemmEngine(cfg, arena=Arena())

    # window=2 keeps exactly two lease sets in flight: enough to overlap
    # planning with device work, small enough that the arena serves the
    # steady stream from its free lists (hit rate near 1).
    n, window = 8, 2

    def serialized():
        for _ in range(n):
            jax.block_until_ready(engine.execute(A, A).C.val)

    def pipelined():
        for _ in range(n):
            engine.submit(A, A)
        out = engine.drain(window=window)
        jax.block_until_ready([r.C.val for r in out.values()])

    def timed(fn) -> float:
        fn()                              # warmup (cold trace + arena fill)
        t0 = time.perf_counter()
        for _ in range(REPS):
            fn()
        return (time.perf_counter() - t0) / REPS

    t_serial = timed(serialized)
    t_pipe = timed(pipelined)
    rows.append(
        f"bench_overlap/async_dispatch,{t_pipe*1e6:.0f},"
        f"serialized_us={t_serial*1e6:.0f};"
        f"overlap_gain={t_serial/t_pipe:.3f}x;"
        f"arena_hit_rate={engine.arena.hit_rate:.3f}")
    print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
