"""§6.3.4/6.3.5 reproduction: dispatch-order load balance + alloc overlap.

  * overlap  — the paper overlaps cudaMalloc with kernel execution; the
    JAX analog is ASYNC DISPATCH: the orchestrator issues device work and
    does host-side planning (bucketing, workspace sizing) without
    blocking.  We measure N independent SpGEMMs issued back-to-back
    (pipelined) vs with a host sync after every step (serialized) — the
    delta is the host time hidden behind device execution.
  * order    — the paper launches large-row kernels first (§5.5).  Our
    hash path dispatches bins largest-first; we measure largest-first vs
    smallest-first dispatch order of the per-bin kernels.
"""
from __future__ import annotations

import time
from typing import List

import jax

from repro.core import SpgemmConfig, spgemm, random_csr

from .common import timeit
from .matrices import generate, NORMAL


def run() -> List[str]:
    rows = []
    spec = NORMAL[7]                      # cage12 analog (mid-size)
    A = generate(spec)
    cfg = SpgemmConfig(method="esc")

    def pipelined(n=4):
        outs = [spgemm(A, A, cfg).C.val for _ in range(n)]
        jax.block_until_ready(outs)       # single sync at the end

    def serialized(n=4):
        for _ in range(n):
            jax.block_until_ready(spgemm(A, A, cfg).C.val)

    t_pipe = timeit(pipelined, reps=3)
    t_serial = timeit(serialized, reps=3)
    rows.append(
        f"bench_overlap/async_dispatch,{t_pipe*1e6:.0f},"
        f"serialized_us={t_serial*1e6:.0f};"
        f"overlap_gain={t_serial/t_pipe:.3f}x")
    print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
