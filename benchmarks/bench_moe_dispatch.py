"""Beyond-paper: MoE dispatch via OpSparse binning vs dense one-hot einsum.

The binning dispatch (core.binning.bin_by_id, the paper's two-pass method)
replaces the GShard-style (T, E, C) one-hot dispatch einsums with sort +
gather/scatter.  Both produce identical outputs (tested); this measures
the cost at growing token counts.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import moe as M
from repro.models.param import init_params

from .common import timeit


def run() -> List[str]:
    rows = []
    cfg = get_arch("olmoe-1b-7b").reduced().replace(
        d_model=256, num_experts=16, experts_per_token=4, d_ff=512,
        moe_capacity_factor=1.25, dtype="float32")
    params = init_params(M.moe_specs(cfg), jax.random.PRNGKey(0))

    for toks in (512, 2048, 8192):
        x = jax.random.normal(jax.random.PRNGKey(1), (4, toks // 4,
                                                      cfg.d_model))
        f_bin = jax.jit(lambda p, x: M.moe(p, x, cfg)[0])
        f_dense = jax.jit(lambda p, x: M.moe_dense_dispatch(p, x, cfg)[0])
        t_bin = timeit(f_bin, params, x)
        t_dense = timeit(f_dense, params, x)
        rows.append(
            f"bench_moe_dispatch/tokens{toks},{t_bin*1e6:.0f},"
            f"dense_us={t_dense*1e6:.0f};binning_speedup="
            f"{t_dense/t_bin:.2f}x")
        print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
