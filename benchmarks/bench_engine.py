"""Plan-cache effectiveness on a streaming request pipeline.

Acceptance targets (ISSUE 1, extended by ISSUE 2 to the hash method): on
a stream of >=20 same-bucket SpGEMM requests, steady-state per-call
wall-clock must be >=5x lower than the first (cold-trace) call, with a
reported plan-cache hit rate >=90% and ZERO retraces after warmup.

The stream models serving traffic: distinct matrices whose storage lands
in one pow-2 capacity bucket, so every request after the first reuses the
cached specialized plan and its jitted executable.  ``--method hash``
exercises the bin-count-bucketed hash steady state: the warmup prefix may
grow the learned launch schedule (rung discovery), after which the gate
requires the jitted path to serve every request without recompiling.  A
second phase pushes the same stream through ``submit``/``drain`` to
exercise the batched, completion-order-finalized path.

``--shards N`` (ISSUE 3) runs the whole stream through the partition-
aware engine: every request fans out into N flop-balanced row-block
shards whose plans must come from the cache (hit rate >=90% across shard
plans, zero retraces after warmup), and the merged result must be
bitwise-identical in nnz/structure to the unsharded path.

``--fused`` (ISSUE 4, hash only) routes steady-state traffic through the
fused symbolic->numeric executable with multi-row VMEM packing: one table
build per row instead of two.  Extra gates: bitwise parity with the
two-pass path on nnz/structure/values, and a measured per-row hash-table
access reduction >= 1.5x vs symbolic+numeric.

``--adaptive`` (ISSUE 5, hash only) runs the stream with NO static
execution knobs: the shard count comes from the AUTO_SHARDS telemetry
policy, the hash-schedule headroom is tracked-jitter (the trim's one
deliberate retrace must land inside warmup, then zero retraces), the
fused path is the default, and steady-state latency must be no worse
than 2x the fixed-2x-headroom baseline previously recorded in
``BENCH_engine.json`` by the plain ``--method hash`` run.

``--arena`` (ISSUE 7) gates the shared workspace arena under a memory
governor: K distinct shape-bucket plans (``--plans``, >= 4) run
concurrently through interleaved ``submit``/``drain`` windows with the
governor capped at 0.6x the per-plan-buffer baseline (the bytes K
private workspaces would pin).  Gates: peak arena bytes <= the cap and
strictly below the baseline, zero retraces after warmup, and bitwise
result parity against a fresh uncapped engine.  Records
``peak_workspace_bytes`` / ``arena_hit_rate`` into the trajectory.

``--estimate`` (ISSUE 8) gates estimation-based cold planning: the same
stream runs twice in ONE process — first under ``plan_mode="estimate"``
(sampled nnz/flop estimator specializes the cold plan; the full symbolic
sizing pass never runs), then under exact planning on a fresh engine.
The ordering biases AGAINST the gate (the exact baseline inherits the
estimate stream's shared jit warmth).  Gates: the estimator must beat
the exact symbolic sizing pass it replaces by >=3x, the full first call
(which fronts the hot-executable compile) must still be no slower than
exact's cold call, zero estimate-stream retraces after warmup
(estimates confirmed, not corrected), steady state no worse than exact,
and bitwise result parity across every request.  Records an
``_estimate``-suffixed trajectory key with the cold-phase breakdown.

``--trace PATH`` enables the engine's structured telemetry layer
(``repro.engine.telemetry``) for the whole run and exports the span log
as a schema-validated Chrome ``trace_event`` file at PATH (plus a JSONL
event log alongside) — load it in Perfetto / ``chrome://tracing`` to see
cold vs steady requests and the sharded fan-out.  Traced runs record
under a ``_traced``-suffixed trajectory key and gate their steady-state
latency at <5% over the tracing-disabled baseline for the same
configuration (the observability tax must stay in the noise).

Every run also records a perf-trajectory artifact at the repo root
(``BENCH_engine.json``): per-configuration steady-state latency (mean
and min of the tail), the cold call's phase breakdown (``phases_ms`` —
span aggregates when traced, the cold request's own per-step timings
otherwise), retrace count, git revision, and — for the hash method —
table-access totals, so future PRs have a baseline to compare against.

Run:  PYTHONPATH=src python benchmarks/bench_engine.py [--smoke]
          [--method hash] [--fused] [--adaptive] [--shards 2]
          [--trace /tmp/trace.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import (SpgemmConfig, bin_rows_for_ladder, next_bucket,
                        nprod_into_rpt, random_csr, spgemm_reference)
from repro.core.analysis import exclusive_sum_in_place
from repro.core.faults import FaultPlan, FaultSpec
from repro.engine import (AdaptivePolicy, Arena, MatrixSig, MemoryGovernor,
                          SpgemmEngine, Telemetry, git_rev, total_traces,
                          utc_now_iso, validate_chrome_trace)
from repro.kernels import spgemm_hash
from repro.serve import SpgemmService

BENCH_JSON = Path(__file__).resolve().parent.parent / "BENCH_engine.json"


def build_stream(n_requests: int, m: int, k: int, n: int, avg: float):
    """Distinct matrices canonicalized to ONE shape-bucket signature."""
    pairs = []
    for s in range(n_requests):
        A = random_csr(jax.random.PRNGKey(2 * s), m, k, avg_nnz_per_row=avg)
        B = random_csr(jax.random.PRNGKey(2 * s + 1), k, n,
                       avg_nnz_per_row=avg)
        pairs.append((A, B))
    # Same-bucket premise: pad every operand to the stream-wide pow-2
    # bucket (the serving tier's batching discipline).
    cap_a = next_bucket(max(A.capacity for A, _ in pairs))
    cap_b = next_bucket(max(B.capacity for _, B in pairs))
    return [(A.with_capacity(cap_a), B.with_capacity(cap_b))
            for A, B in pairs]


def measure_hash_accesses(A, B, config: SpgemmConfig, *,
                          with_fused: bool = True):
    """Fig.-9 access counters on one pair: two-pass vs fused table builds.

    Returns ``(sym, num, fused)`` total table-transaction counts; the
    fused build replaces sym+num, so ``(sym + num) / fused`` is the
    measured per-call access reduction.  ``with_fused=False`` skips the
    fused counter (None) so non-fused gates never touch the fused kernels.
    """
    m = A.nrows
    sym_lad, num_lad = config.ladders()
    nprod = nprod_into_rpt(A, B)[:m]
    sym_bn = bin_rows_for_ladder(nprod, sym_lad)
    nnz_buf, acc_s = spgemm_hash.symbolic_binned(
        A, B, sym_bn, sym_lad, single_access=config.hash_single_access,
        interpret=config.interpret, collect_accesses=True)
    num_bn = bin_rows_for_ladder(nnz_buf[:m], num_lad)
    cap = next_bucket(max(int(nnz_buf[:m].sum()), 1))
    rpt = exclusive_sum_in_place(nnz_buf)
    _, acc_n = spgemm_hash.numeric_binned(
        A, B, rpt, num_bn, num_lad, nnz_capacity=cap,
        single_access=config.hash_single_access,
        interpret=config.interpret, collect_accesses=True)
    if not with_fused:
        return int(acc_s), int(acc_n), None
    _, acc_f = spgemm_hash.fused_binned(
        A, B, sym_bn, sym_lad, nnz_capacity=cap,
        single_access=config.hash_single_access,
        interpret=config.interpret, row_packing=config.row_packing,
        collect_accesses=True)
    return int(acc_s), int(acc_n), int(acc_f)


def record_trajectory(key: str, entry: dict) -> None:
    """Merge one configuration's results into ``BENCH_engine.json``.

    An unparseable file (e.g. a run killed mid-write) is set aside as
    ``BENCH_engine.json.corrupt`` instead of silently clobbered — the
    trajectory is the baseline future PRs compare against.
    """
    payload = {}
    if BENCH_JSON.exists():
        try:
            payload = json.loads(BENCH_JSON.read_text())
        except (ValueError, OSError):
            corrupt = BENCH_JSON.with_suffix(".json.corrupt")
            BENCH_JSON.rename(corrupt)
            print(f"WARNING: unreadable {BENCH_JSON.name} preserved as "
                  f"{corrupt.name}; starting a fresh trajectory",
                  file=sys.stderr)
    payload[key] = entry
    BENCH_JSON.write_text(json.dumps(payload, indent=1, sort_keys=True)
                          + "\n")


def result_parity(base, res, *, bitwise_val: bool) -> bool:
    """nnz/rpt/col/val parity of two SpgemmResults (bitwise structure;
    values bitwise or allclose — sharded merges may reorder FP sums)."""
    nnz = base.total_nnz
    val_eq = np.array_equal if bitwise_val else np.allclose
    return (
        res.total_nnz == nnz
        and np.array_equal(np.asarray(res.C.rpt), np.asarray(base.C.rpt))
        and np.array_equal(np.asarray(res.C.col)[:nnz],
                           np.asarray(base.C.col)[:nnz])
        and val_eq(np.asarray(res.C.val)[:nnz],
                   np.asarray(base.C.val)[:nnz]))


def _lease_bytes(spec) -> int:
    """Bucketed bytes one plan's workspace lease pins (the per-plan-
    buffer baseline sums these: without the arena each plan would hold
    its own pair for its whole cache lifetime)."""
    return sum(Arena._bucket_bytes(k) for k in Arena._buckets(spec))


def run_arena_gate(args) -> int:
    """ISSUE 7 acceptance: K distinct shape-bucket plans served
    concurrently out of one governor-capped arena.

    The per-plan-buffer baseline is what the pre-arena engine pinned:
    every cached plan holding a private workspace pair sized to its own
    bucket.  The arena gate runs the same K plans through interleaved
    submit/drain windows with the governor capped at 0.6x that baseline
    and requires the measured peak to stay under the cap — lease reuse
    across requests (and across same-bucket plans) is what makes the
    window, not the plan count, the working-set bound.
    """
    cfg = SpgemmConfig(method=args.method)
    K, rounds, window = args.plans, 3, 3
    # Distinct nrows => distinct MatrixSigs => K separate cached plans.
    pairs = []
    for i in range(K):
        m = args.m + 8 * i
        A = random_csr(jax.random.PRNGKey(2 * i), m, args.k,
                       avg_nnz_per_row=args.avg)
        B = random_csr(jax.random.PRNGKey(2 * i + 1), args.k, args.n,
                       avg_nnz_per_row=args.avg)
        pairs.append((A, B))

    engine = SpgemmEngine(cfg, arena=Arena())
    for A, B in pairs:                    # cold (steps) + hot (first lease)
        engine.execute(A, B)
        jax.block_until_ready(engine.execute(A, B).C.val)

    entries = [engine.cache.get((MatrixSig.of(A), MatrixSig.of(B), cfg))
               for A, B in pairs]
    specs = [e.plan.workspace_spec() for e in entries]
    assert all(s is not None for s in specs), "unleasable plan in the gate"
    baseline = sum(_lease_bytes(s) for s in specs)
    cap = int(0.6 * baseline)
    engine.governor = MemoryGovernor(cap_bytes=cap)
    engine.arena.reclaim()               # drop warmup leases: cap must bind
    engine.arena.reset_peak()
    hits0 = engine.arena.lease_hits
    misses0 = engine.arena.lease_misses
    warm_traces = total_traces()

    last = None
    t0 = time.perf_counter()
    for _ in range(rounds):
        uids = [engine.submit(A, B) for A, B in pairs]
        results = engine.drain(window=window)
        jax.block_until_ready([results[u].C.val for u in uids])
        last = [results[u] for u in uids]
    traffic_s = time.perf_counter() - t0
    n_reqs = rounds * K

    peak = engine.arena.peak_bytes
    retraces = total_traces() - warm_traces
    hits = engine.arena.lease_hits - hits0
    misses = engine.arena.lease_misses - misses0
    hit_rate = hits / max(hits + misses, 1)

    # Bitwise parity: an uncapped fresh engine (own arena) must produce
    # byte-identical results — governor pressure and lease recycling are
    # not allowed to change a single bit of the output.
    fresh = SpgemmEngine(cfg, arena=Arena())
    parity = True
    for (A, B), res in zip(pairs, last):
        fresh.execute(A, B)
        base = fresh.execute(A, B)       # hot path, like the gated stream
        parity = parity and result_parity(base, res, bitwise_val=True)

    cap_ok = peak <= cap
    base_ok = peak < baseline
    print(f"plans:         {K:9d} distinct shape buckets "
          f"({rounds} rounds, window {window})")
    print(f"baseline:      {baseline:9d} B  (per-plan private workspaces)")
    print(f"governor cap:  {cap:9d} B  (0.6x baseline)")
    print(f"arena peak:    {peak:9d} B  "
          f"({peak / baseline:.2f}x baseline, "
          f"{'OK' if cap_ok and base_ok else 'OVER'})")
    print(f"lease reuse:   {hits:9d} hits / {misses} misses "
          f"({hit_rate * 100:.1f}% hit rate, "
          f"{engine.stats.arena_pressure} pressure events)")
    print(f"hot traces:    {total_traces():9d}  "
          f"({retraces} after warmup, target 0)")
    print(f"parity:        {'OK' if parity else 'MISMATCH':>9s}  "
          f"(capped arena vs fresh engine: nnz/rpt/col/val bitwise)")
    print(f"traffic:       {traffic_s * 1e3:9.1f} ms for {n_reqs} requests "
          f"({traffic_s / n_reqs * 1e3:.2f} ms/req)")
    print()
    print(engine.report())

    key = f"{args.method}_arena@{args.m}x{args.k}x{args.n}k{K}"
    record_trajectory(key, {
        "plans": K,
        "rounds": rounds,
        "window": window,
        "shape": [args.m, args.k, args.n],
        "baseline_workspace_bytes": baseline,
        "governor_cap_bytes": cap,
        "peak_workspace_bytes": peak,
        "peak_over_baseline": round(peak / baseline, 4),
        "arena_hit_rate": round(hit_rate, 4),
        "pressure_events": engine.stats.arena_pressure,
        "retraces_after_warmup": retraces,
        "traffic_ms_per_request": round(traffic_s / n_reqs * 1e3, 4),
        "git_rev": git_rev(BENCH_JSON.parent),
        "recorded_at": utc_now_iso(),
    })
    print(f"trajectory:    {BENCH_JSON.name} <- {key}")

    ok = cap_ok and base_ok and retraces == 0 and parity
    print()
    print("PASS" if ok else "FAIL",
          f"(peak {peak} B vs cap {cap} B / baseline {baseline} B, "
          f"{retraces} retraces, hit rate {hit_rate * 100:.1f}%"
          + ("" if cap_ok else ", peak over governor cap")
          + ("" if base_ok else ", peak not below per-plan baseline")
          + ("" if parity else ", parity MISMATCH")
          + ")")
    return 0 if ok else 1


def run_estimate_gate(args) -> int:
    """ISSUE 8 acceptance: estimation-based cold-path planning.

    The SAME request stream runs twice in one process, ordered so the
    measurement bias runs AGAINST the gate: the ``plan_mode="estimate"``
    stream goes FIRST (truly cold — its first call pays every shared
    one-time cost), then the exact-planning baseline runs on a fresh
    engine SECOND, inheriting whatever kernel-cache warmth the estimate
    stream built.  The exact cold call still compiles the standalone
    six-step jits the estimate path never touches, which is precisely
    the cost the estimator exists to skip.
    """
    stream = build_stream(args.requests, args.m, args.k, args.n, args.avg)

    def run_stream(config):
        engine = SpgemmEngine(config)
        times, results = [], []
        warm = total_traces()
        for i, (A, B) in enumerate(stream):
            t0 = time.perf_counter()
            res = engine.execute(A, B)
            jax.block_until_ready(res.C.val)
            times.append(time.perf_counter() - t0)
            results.append(res)
            if i == args.warmup - 1:
                # Absorb any pending schedule rebuild before the gate arms
                # (same discipline as the main stream gate).
                jax.block_until_ready(engine.execute(A, B).C.val)
                warm = total_traces()
            if args.check:
                ref = np.asarray(spgemm_reference(A, B))
                np.testing.assert_allclose(np.asarray(res.C.to_dense()),
                                           ref, rtol=1e-4, atol=1e-4)
        return engine, times, results, total_traces() - warm

    est_engine, est_t, est_res, retraces = run_stream(
        SpgemmConfig(method=args.method, plan_mode="estimate"))
    exact_engine, ex_t, ex_res, _ = run_stream(
        SpgemmConfig(method=args.method))

    est_cold, ex_cold = est_t[0], ex_t[0]
    est_tail = est_t[len(est_t) // 2:]
    ex_tail = ex_t[len(ex_t) // 2:]
    est_steady, ex_steady = min(est_tail), min(ex_tail)
    parity = all(result_parity(b, r, bitwise_val=True)
                 for b, r in zip(ex_res, est_res))
    phases_ms = {n: round(t * 1e3, 3)
                 for n, t in sorted(est_res[0].timings.items())}

    # The tentpole gate compares the sizing pass against its replacement:
    # the exact cold call IS the full symbolic sizing pass (its per-step
    # kernels exist only to size the plan; the hot executable both modes
    # compile afterwards is common cost), and the "estimate" phase is
    # what stands in for it.  The full first-call walls are gated too —
    # the estimate path fronts the hot-executable compile into call one,
    # and that must still not make the first call slower than exact's.
    plan_ms = phases_ms.get("estimate", 0.0)
    plan_ratio = ex_cold * 1e3 / max(plan_ms, 1e-6)
    plan_ok = plan_ratio >= 3.0 and plan_ms > 0.0
    cold_ok = est_cold <= ex_cold
    retrace_ok = retraces == 0
    # min-of-tail with tolerance: the steady executables are IDENTICAL in
    # shape (only planning differed), so any gap is ambient-load jitter —
    # which on a shared CI host routinely exceeds a strict bound.
    steady_ok = est_steady <= 1.5 * ex_steady
    # Every estimated plan must resolve: confirmed by an admitted
    # finalize or (inside warmup) corrected by the overflow retrace.
    s = est_engine.stats
    resolved_ok = s.estimates > 0 and (
        s.estimate_hits + s.estimate_misses >= s.estimates)

    print(f"method:        {args.method:>9s}  (plan_mode=estimate vs exact)")
    print(f"sizing pass:   {plan_ms:9.1f} ms estimate vs "
          f"{ex_cold * 1e3:.1f} ms exact symbolic sizing = "
          f"{plan_ratio:.1f}x ({'OK' if plan_ok else 'BELOW 3x'})")
    print(f"cold call:     {est_cold * 1e3:9.1f} ms estimate "
          f"(plan + hot compile) vs {ex_cold * 1e3:.1f} ms exact "
          f"(sizing only; hot compile lands on call 2) "
          f"({'OK' if cold_ok else 'WORSE'})")
    print(f"cold phases:   " + ", ".join(
        f"{n} {t:.1f} ms" for n, t in phases_ms.items()))
    print(f"steady state:  {est_steady * 1e3:9.2f} ms estimate vs "
          f"{ex_steady * 1e3:.2f} ms exact min-of-tail "
          f"({'OK' if steady_ok else 'WORSE'})")
    print(f"estimates:     {s.estimates:9d} plans "
          f"({s.estimate_hits} confirmed / {s.estimate_misses} retraced, "
          f"headroom {est_engine.est_state.headroom:.2f})")
    print(f"retraces:      {retraces:9d} after {args.warmup}-request "
          f"warmup (target 0)")
    print(f"parity:        {'OK' if parity else 'MISMATCH':>9s}  "
          f"(estimate vs exact stream: nnz/rpt/col/val bitwise, "
          f"{len(stream)} requests)")
    print()
    print(est_engine.report())

    key = (f"{args.method}_estimate"
           f"@{args.m}x{args.k}x{args.n}r{args.requests}")
    record_trajectory(key, {
        "requests": args.requests,
        "shape": [args.m, args.k, args.n],
        "cold_ms": round(est_cold * 1e3, 3),
        "exact_cold_ms": round(ex_cold * 1e3, 3),
        "plan_ms": round(plan_ms, 3),
        "plan_speedup": round(plan_ratio, 2),
        "steady_min_ms": round(est_steady * 1e3, 4),
        "exact_steady_min_ms": round(ex_steady * 1e3, 4),
        "phases_ms": phases_ms,
        "estimates": s.estimates,
        "estimate_hits": s.estimate_hits,
        "estimate_misses": s.estimate_misses,
        "retraces_after_warmup": retraces,
        "git_rev": git_rev(BENCH_JSON.parent),
        "recorded_at": utc_now_iso(),
    })
    print(f"trajectory:    {BENCH_JSON.name} <- {key}")

    ok = (plan_ok and cold_ok and retrace_ok and steady_ok and parity
          and resolved_ok)
    print()
    print("PASS" if ok else "FAIL",
          f"(sizing {plan_ratio:.1f}x vs exact, {retraces} retraces, "
          f"{s.estimate_hits}/{s.estimates} estimates confirmed"
          + ("" if plan_ok else ", sizing advantage < 3x")
          + ("" if cold_ok else ", first call slower than exact cold")
          + ("" if steady_ok else ", steady state worse than exact")
          + ("" if parity else ", parity MISMATCH")
          + ("" if resolved_ok else ", unresolved estimated plans")
          + ")")
    return 0 if ok else 1


def run_serve_gate(args) -> int:
    """ISSUE 9 acceptance: the fault-tolerant serving front-end (chaos
    gate).

    A mixed-tenant request stream runs twice: fault-free, then under a
    seeded :class:`FaultPlan` arming lease denials and verify overflows
    probabilistically across the whole stream.  The gate requires ZERO
    failed well-formed requests under chaos, every chaos result bitwise
    identical to its fault-free twin, and the chaos p99 latency bounded
    relative to fault-free (recovery redos cost about a cold call, not
    more).  Two targeted scenarios then check the structured-failure
    contract — a poisoned (non-transient) request errors WITHOUT a
    retry, a stalled request under a deadline returns a timeout — and
    the per-tenant counters are asserted on a live ``/metrics`` scrape.
    """
    import urllib.request

    cfg = SpgemmConfig(method=args.method)
    stream = build_stream(args.requests, args.m, args.k, args.n, args.avg)
    tenants = ["alpha", "beta"]
    assign = [tenants[i % 2] for i in range(len(stream))]

    def run_service(faults=None):
        svc = SpgemmService(cfg, arena=Arena(), faults=faults,
                            backoff_base_s=1e-3, backoff_cap_s=0.05)
        outs, lats = [], []
        for (A, B), ten in zip(stream, assign):
            t0 = time.perf_counter()
            r = svc.call(A, B, tenant=ten, deadline_s=60.0)
            if r.ok:
                jax.block_until_ready(r.value.C.val)
            lats.append(time.perf_counter() - t0)
            outs.append(r)
        return svc, outs, lats

    def p99(lats):
        return sorted(lats)[min(len(lats) - 1, int(0.99 * len(lats)))]

    # ---- phase 1: chaos stream vs fault-free twin -------------------------
    _, clean, clean_lats = run_service()
    chaos_plan = FaultPlan([
        # Deterministic double denial: visits 5 and 6 are one request's
        # initial + post-reclaim acquisition attempts (or two requests'
        # worth under earlier probabilistic denials) — either way at
        # least one ArenaPressureError reaches the service retry loop.
        FaultSpec(site="lease_denial", at=(5, 6)),
        FaultSpec(site="lease_denial", probability=0.25),
        FaultSpec(site="verify_overflow", probability=0.15),
    ], seed=args.seed)
    svc, chaos, chaos_lats = run_service(chaos_plan)

    failed = [i for i, r in enumerate(chaos) if not r.ok]
    parity = all(
        r.ok and result_parity(c.value, r.value, bitwise_val=True)
        for c, r in zip(clean, chaos))
    retries = sum(r.retries for r in chaos)
    survived = sum(r.faults_survived for r in chaos)
    injected = chaos_plan.total_injected
    p99_clean, p99_chaos = p99(clean_lats), p99(chaos_lats)
    # Injected overflows redo through the steps oracle (~a cold call) and
    # denials add backoff sleeps; the clean p99 is ALSO a cold call, so a
    # generous multiple plus a wall-clock floor absorbs CI timer noise.
    p99_bound = max(5.0 * p99_clean, 0.5)
    p99_ok = p99_chaos <= p99_bound

    # ---- phase 2: structured-failure contract -----------------------------
    A0, B0 = stream[0]
    svc_poison = SpgemmService(cfg, arena=Arena(), faults=FaultPlan(
        [FaultSpec(site="executor_raise", at=(0,), message="poisoned")]))
    r_poison = svc_poison.call(A0, B0, tenant="alpha")
    poison_ok = (r_poison.status == "error" and r_poison.retries == 0
                 and "poisoned" in r_poison.error)

    svc_slow = SpgemmService(cfg, arena=Arena(), faults=FaultPlan(
        [FaultSpec(site="slow_dispatch", at=(1,), delay_s=0.3)]))
    svc_slow.call(A0, B0, tenant="alpha")        # warm: latency history
    r_slow = svc_slow.call(A0, B0, tenant="alpha", deadline_s=0.05)
    deadline_ok = r_slow.status == "timeout" and r_slow.value is None

    # ---- phase 3: live /metrics scrape ------------------------------------
    server = svc.serve_http()
    try:
        body = urllib.request.urlopen(server.url, timeout=10).read().decode()
    finally:
        svc.close()
    scrape_ok = all(
        f'opsparse_service_requests_total{{tenant="{t}"}}' in body
        for t in tenants) and all(
        name in body for name in (
            "opsparse_service_retries_total",
            "opsparse_service_timeouts_total",
            "opsparse_service_sheds_total",
            "opsparse_service_faults_survived_total",
            "opsparse_engine_faults_injected_total"))

    n = len(stream)
    print(f"stream:        {n:9d} requests over {len(tenants)} tenants "
          f"(seed {args.seed})")
    print(f"chaos:         {injected:9d} faults injected "
          f"({retries} service retries, {survived} survived on ok paths)")
    print(f"failures:      {len(failed):9d} failed well-formed requests "
          f"(target 0){'' if not failed else ' -> ' + str(failed)}")
    print(f"parity:        {'OK' if parity else 'MISMATCH':>9s}  "
          f"(chaos vs fault-free twin: nnz/rpt/col/val bitwise)")
    print(f"p99 latency:   {p99_chaos * 1e3:9.1f} ms under chaos vs "
          f"{p99_clean * 1e3:.1f} ms clean "
          f"(bound {p99_bound * 1e3:.0f} ms, "
          f"{'OK' if p99_ok else 'OVER'})")
    print(f"poisoned req:  {r_poison.status:>9s}  "
          f"({r_poison.retries} retries, target error/0)")
    print(f"deadline req:  {r_slow.status:>9s}  (injected stall vs 50 ms "
          f"budget, target timeout)")
    print(f"scrape:        {'OK' if scrape_ok else 'MISSING':>9s}  "
          f"(per-tenant series on live /metrics)")

    key = f"{args.method}_serve@{args.m}x{args.k}x{args.n}"
    record_trajectory(key, {
        "requests": n,
        "tenants": tenants,
        "shape": [args.m, args.k, args.n],
        "seed": args.seed,
        "faults_injected": injected,
        "fault_sites": chaos_plan.snapshot()["injected"],
        "service_retries": retries,
        "faults_survived": survived,
        "failed_requests": len(failed),
        "p99_clean_ms": round(p99_clean * 1e3, 3),
        "p99_chaos_ms": round(p99_chaos * 1e3, 3),
        "git_rev": git_rev(BENCH_JSON.parent),
        "recorded_at": utc_now_iso(),
    })
    print(f"trajectory:    {BENCH_JSON.name} <- {key}")

    ok = (not failed and parity and p99_ok and poison_ok and deadline_ok
          and scrape_ok and injected > 0)
    print()
    print("PASS" if ok else "FAIL",
          f"({n} requests, {injected} faults, {len(failed)} failures"
          + ("" if parity else ", parity MISMATCH")
          + ("" if p99_ok else ", p99 over bound")
          + ("" if poison_ok else ", poisoned-request contract broken")
          + ("" if deadline_ok else ", deadline contract broken")
          + ("" if scrape_ok else ", /metrics series missing")
          + ("" if injected > 0 else ", no faults injected — gate inert")
          + ")")
    return 0 if ok else 1


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (~30 s)")
    ap.add_argument("--method", choices=("esc", "hash"), default="esc",
                    help="accumulator method for the whole stream")
    ap.add_argument("--fused", action="store_true",
                    help="hash only: fused one-build steady state with "
                         "row packing (gates access reduction + parity)")
    ap.add_argument("--adaptive", action="store_true",
                    help="hash only: telemetry-driven policy — AUTO shard "
                         "count, tracked-jitter headroom (trim inside "
                         "warmup), fused-by-default; gates zero steady-"
                         "state retraces and steady latency no worse than "
                         "the fixed-2x baseline in BENCH_engine.json")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--warmup", type=int, default=None,
                    help="requests before the zero-retrace gate arms "
                         "(cold call + schedule/rung discovery; default 4, "
                         "or 12 under --adaptive so the headroom trim "
                         "lands inside warmup)")
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--avg", type=float, default=4.0)
    ap.add_argument("--shards", type=int, default=1,
                    help="row-block shards per request (partition-aware "
                         "engine; 1 = unsharded)")
    ap.add_argument("--arena", action="store_true",
                    help="workspace-arena gate: K distinct shape-bucket "
                         "plans (--plans) under a governor cap of 0.6x "
                         "the per-plan-buffer baseline; gates peak bytes, "
                         "zero retraces, bitwise parity")
    ap.add_argument("--plans", type=int, default=8,
                    help="arena gate: number of distinct shape buckets "
                         "(>= 4)")
    ap.add_argument("--estimate", action="store_true",
                    help="estimation-based cold-planning gate: run the "
                         "stream under plan_mode='estimate' first (cold), "
                         "then an exact-planning baseline on a fresh "
                         "engine in the same process; gates cold-call "
                         ">=3x, zero post-warmup retraces, steady state "
                         "no worse, bitwise parity")
    ap.add_argument("--serve", action="store_true",
                    help="chaos gate for the fault-tolerant serving "
                         "front-end: a mixed-tenant stream under a seeded "
                         "FaultPlan; gates zero failed requests, bitwise "
                         "parity vs a fault-free run, bounded p99 "
                         "inflation, structured error/timeout contracts, "
                         "and per-tenant /metrics series")
    ap.add_argument("--seed", type=int, default=0,
                    help="serve gate: FaultPlan seed (same seed => same "
                         "injections)")
    ap.add_argument("--check", action="store_true",
                    help="verify every result against the dense oracle")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable telemetry and export a schema-validated "
                         "Chrome trace_event file to PATH (+ a .jsonl "
                         "event log alongside); gates traced steady "
                         "latency at <5%% over the tracing-disabled "
                         "baseline in BENCH_engine.json")
    args = ap.parse_args(argv)
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if args.smoke:
        args.requests, args.m, args.k, args.n = 20, 64, 64, 64
    if args.warmup is None:
        args.warmup = 12 if args.adaptive else 4
    if not 0 < args.warmup < args.requests:
        ap.error("--warmup must be in [1, effective --requests)")
    if args.fused and args.method != "hash":
        ap.error("--fused requires --method hash")
    if args.adaptive and args.method != "hash":
        ap.error("--adaptive requires --method hash")
    if args.adaptive and args.shards > 1:
        ap.error("--adaptive picks the shard count itself; drop --shards")
    if args.adaptive and args.fused:
        ap.error("--adaptive already runs the fused-by-default config; "
                 "drop --fused (its packing/access gates assume a static "
                 "row_packing setup)")
    if args.arena:
        if args.fused or args.adaptive or args.shards > 1 or args.estimate \
                or args.serve:
            ap.error("--arena is its own gate; drop --fused/--adaptive/"
                     "--shards/--estimate/--serve")
        if args.plans < 4:
            ap.error("--plans must be >= 4 (the gate is about concurrent "
                     "shape buckets)")
        return run_arena_gate(args)
    if args.estimate:
        if args.fused or args.adaptive or args.shards > 1 or args.trace \
                or args.serve:
            ap.error("--estimate is its own gate; drop --fused/--adaptive/"
                     "--shards/--trace/--serve")
        return run_estimate_gate(args)
    if args.serve:
        if args.fused or args.adaptive or args.shards > 1 or args.trace \
                or args.estimate:
            ap.error("--serve is its own gate; drop --fused/--adaptive/"
                     "--shards/--trace/--estimate")
        return run_serve_gate(args)

    stream = build_stream(args.requests, args.m, args.k, args.n, args.avg)
    # --trace flips the engine's telemetry layer on for the WHOLE stream
    # (cold calls included: the Perfetto view's point is cold vs steady).
    # The ring is sized to hold a full run so the export isn't truncated.
    telemetry = (Telemetry(enabled=True, events_capacity=1 << 16)
                 if args.trace else None)
    if args.adaptive:
        # No static knobs: fused-by-default config, AUTO shard count, and
        # a trim streak short enough that the headroom shrink (one
        # deliberate retrace) lands inside the warmup window.
        config = SpgemmConfig(method="hash")
        engine = SpgemmEngine(config, shards="auto",
                              policy=AdaptivePolicy(trim_streak=6),
                              telemetry=telemetry)
    else:
        config = SpgemmConfig(method=args.method, fuse_numeric=args.fused,
                              row_packing=args.fused)
        engine = SpgemmEngine(config, shards=args.shards,
                              telemetry=telemetry)

    # ---- phase 1: per-call wall-clock over the stream ---------------------
    times = []
    warm_traces = 0
    cold_phases = None
    for i, (A, B) in enumerate(stream):
        t0 = time.perf_counter()
        res = engine.execute(A, B)
        jax.block_until_ready(res.C.val)
        times.append(time.perf_counter() - t0)
        if i == 0 and res.timings:
            # The truly-cold call keeps its StepTimer on even untraced, so
            # the trajectory gets the cold-phase breakdown for free.
            cold_phases = {n: round(t * 1e3, 3)
                           for n, t in sorted(res.timings.items())}
        if i == args.warmup - 1:
            # A schedule grow on this very request leaves the rebuild (and
            # its one retrace) pending; absorb it with an untimed repeat of
            # an already-admitted pair before the gate arms.
            jax.block_until_ready(engine.execute(A, B).C.val)
            warm_traces = total_traces()   # retrace gate arms here
        if args.check:
            ref = np.asarray(spgemm_reference(A, B))
            np.testing.assert_allclose(np.asarray(res.C.to_dense()), ref,
                                       rtol=1e-4, atol=1e-4)

    cold = times[0]
    tail = times[len(times) // 2:]
    steady = sum(tail) / len(tail)
    steady_min = min(tail)     # noise-robust statistic (overhead gates)
    speedup = cold / steady
    hit_rate = engine.cache.hit_rate
    retraces = total_traces() - warm_traces

    print("request,call_ms")
    for i, t in enumerate(times):
        print(f"{i},{t * 1e3:.2f}")
    print()
    print(f"method:        {args.method:>9s}")
    print(f"cold call:     {cold * 1e3:9.1f} ms  (trace + compile)")
    print(f"steady state:  {steady * 1e3:9.2f} ms  "
          f"(mean of last {len(tail)} calls)")
    print(f"speedup:       {speedup:9.1f} x   (target >= 5x)")
    print(f"hit rate:      {hit_rate * 100:9.1f} %   (target >= 90%)")
    print(f"hot traces:    {total_traces():9d}  "
          f"({retraces} after {args.warmup}-request warmup, target 0)")

    # ---- sharded parity: merged C must match the unsharded path ----------
    parity = True
    if args.shards > 1:
        A0, B0 = stream[0]
        base = SpgemmEngine(SpgemmConfig(method=args.method)).execute(A0, B0)
        parity = result_parity(base, engine.execute(A0, B0),
                               bitwise_val=False)
        print(f"shard parity:  {'OK' if parity else 'MISMATCH':>9s}  "
              f"({args.shards} shards vs unsharded: nnz/rpt/col/val)")

    # ---- fused gates: bitwise parity with two-pass + access reduction -----
    # The fused kernels are exercised only under --fused, so the plain
    # --method hash gate keeps isolating two-pass regressions.
    access = None
    access_ok = True
    if args.method == "hash":
        A0, B0 = stream[0]
        acc_s, acc_n, acc_f = measure_hash_accesses(
            A0, B0, config, with_fused=args.fused)
        access = {"symbolic": acc_s, "numeric": acc_n, "fused": acc_f}
        if args.fused:
            reduction = (acc_s + acc_n) / max(acc_f, 1)
            access["reduction"] = round(reduction, 3)
            access_ok = reduction >= 1.5
            print(f"table access:  {acc_s + acc_n:9d} two-pass (sym {acc_s} "
                  f"+ num {acc_n}) vs {acc_f} fused = "
                  f"{reduction:.2f}x reduction")
            base = SpgemmEngine(SpgemmConfig(
                method="hash", fuse_numeric=False)).execute(A0, B0)
            fused_parity = result_parity(base, engine.execute(A0, B0),
                                         bitwise_val=True)
            print(f"fused parity:  {'OK' if fused_parity else 'MISMATCH':>9s}"
                  f"  (fused vs two-pass oracle: nnz/rpt/col/val bitwise)")
            parity = parity and fused_parity   # keep any shard MISMATCH
        else:
            print(f"table access:  {acc_s + acc_n:9d} two-pass "
                  f"(sym {acc_s} + num {acc_n})")

    # ---- adaptive gates: no static knobs, parity, headroom latency --------
    headroom_ok = True
    policy_ok = True
    if args.adaptive:
        # Every request went through the policy (shard count and headroom
        # came from telemetry, not knobs); a gate, not an assert — it must
        # survive python -O and reach the FAIL reporting path.
        policy_ok = engine.stats.auto_requests >= args.requests
        decisions = sorted({e.plan.policy.shard_decision
                            for _, e in engine.cache.items()
                            if e.plan.policy is not None
                            and e.plan.policy.shard_decision is not None})
        headrooms = sorted({round(e.plan.policy.headroom, 3)
                            for _, e in engine.cache.items()
                            if e.plan.policy is not None
                            and e.plan.hash_schedule is not None})
        print(f"policy:        shards->{decisions} headroom={headrooms} "
              f"({engine.stats.schedule_trims} schedule trims, "
              f"{engine.stats.policy_revisions} shard revisions)")
        # ... the fused default stays faithful to the two-pass oracle
        # (bitwise when unsharded; a sharded merge keeps structure bitwise
        # but may reorder FP sums) ...
        A0, B0 = stream[0]
        base = SpgemmEngine(
            SpgemmConfig(method="hash", fuse_numeric=False)).execute(A0, B0)
        adaptive_parity = result_parity(
            base, engine.execute(A0, B0),
            bitwise_val=engine.stats.sharded_requests == 0)
        print(f"adapt parity:  "
              f"{'OK' if adaptive_parity else 'MISMATCH':>9s}  "
              f"(fused-default vs two-pass oracle)")
        parity = parity and adaptive_parity
        # ... and the tracked headroom is no worse than the fixed-2x
        # baseline this file's plain --method hash run recorded (2x wall-
        # clock tolerance: interpret-mode timings are noisy).
        fixed_key = f"hash@{args.m}x{args.k}x{args.n}r{args.requests}"
        try:
            fixed = json.loads(BENCH_JSON.read_text()).get(fixed_key)
        except (ValueError, OSError):
            fixed = None
        if fixed is not None:
            headroom_ok = steady * 1e3 <= 2.0 * fixed["steady_ms"]
            print(f"vs fixed 2x:   {steady * 1e3:9.2f} ms adaptive vs "
                  f"{fixed['steady_ms']:.2f} ms fixed "
                  f"({'OK' if headroom_ok else 'WORSE'})")
        else:
            print(f"vs fixed 2x:   no '{fixed_key}' baseline in "
                  f"{BENCH_JSON.name}; run --method hash first to arm "
                  f"the latency gate")

    # ---- phase 2: batched submit/drain (double-buffered overlap) ----------
    uids = [engine.submit(A, B) for A, B in stream]
    t0 = time.perf_counter()
    results = engine.drain()
    jax.block_until_ready([results[u].C.val for u in uids])
    drain_s = time.perf_counter() - t0
    print(f"drain:         {drain_s * 1e3:9.1f} ms for {len(uids)} requests "
          f"({drain_s / len(uids) * 1e3:.2f} ms/req, "
          f"{engine.stats.overlapped} overlapped, "
          f"{engine.stats.reordered} reordered)")
    print()
    print(engine.report())

    # ---- trajectory key (shared by the trace gate below) ------------------
    # The workload shape is part of the key so a --smoke run never
    # overwrites a full-size baseline recorded for the same config.
    key = args.method + ("_fused" if args.fused else "")
    if args.adaptive:
        key += "_adaptive"
    if args.shards > 1:
        key += f"_shards{args.shards}"
    key += f"@{args.m}x{args.k}x{args.n}r{args.requests}"

    # ---- trace export + telemetry gates -----------------------------------
    # Untraced runs report the cold request's own per-step timings; traced
    # runs override with the aggregated span durations below.
    phases_ms = cold_phases
    trace_tax = None
    trace_ok = True
    overhead_ok = True
    if args.trace:
        trace_path = Path(args.trace)
        telemetry.export_chrome_trace(trace_path)
        jsonl_path = trace_path.with_suffix(".jsonl")
        n_jsonl = telemetry.export_jsonl(jsonl_path)
        n_events = validate_chrome_trace(trace_path)   # raises on bad schema
        spans = telemetry.finished_spans()
        names = {s["name"] for s in spans}
        # The acceptance trace must show the full nested pipeline.
        required = {"request", "plan_lookup", "dispatch", "cold_steps",
                    "symbolic", "numeric", "verify_sync", "finalize",
                    "drain"}
        if args.shards > 1:
            required |= {"shard", "partition", "shard_merge"}
        missing = sorted(required - names)
        trace_ok = not missing
        agg = {}
        for s in spans:
            agg[s["name"]] = agg.get(s["name"], 0.0) + s["dur"]
        phases_ms = {n: round(t * 1e3, 3) for n, t in sorted(agg.items())}
        print(f"trace:         {n_events} trace_event records -> "
              f"{trace_path} (+{n_jsonl} JSONL rows), "
              f"{telemetry.events.dropped} ring overflows"
              + ("" if trace_ok else f"; MISSING spans {missing}"))
        # Overhead gate: tracing must add <5% to steady-state latency.
        # Ambient machine load routinely swings a ~2 ms CPU workload by
        # more than 5% between two separate processes, so the GATE is a
        # same-process A/B: re-run the steady tail on this same engine
        # (same plans, same executables) with tracing on, then off,
        # twice each in alternation, and compare min-of-tail — adjacent
        # loops see the same ambient load, so the ratio isolates the
        # tracing cost.  The cross-run number vs the untraced baseline
        # in BENCH_engine.json is still printed for the trajectory.
        def steady_pass():
            ts = []
            for A, B in stream[len(stream) // 2:]:
                t0 = time.perf_counter()
                res = engine.execute(A, B)
                jax.block_until_ready(res.C.val)
                ts.append(time.perf_counter() - t0)
            return min(ts)

        traced_min, control_min = float("inf"), float("inf")
        for _ in range(2):
            engine.telemetry.enabled = True
            traced_min = min(traced_min, steady_pass())
            engine.telemetry.enabled = False
            control_min = min(control_min, steady_pass())
        engine.telemetry.enabled = True
        overhead_ok = traced_min <= 1.05 * control_min
        trace_tax = {"traced_min_ms": round(traced_min * 1e3, 4),
                     "control_min_ms": round(control_min * 1e3, 4)}
        print(f"trace tax:     {traced_min * 1e3:9.2f} ms traced vs "
              f"{control_min * 1e3:.2f} ms tracing-off steady-min "
              f"(same-process A/B, "
              f"{'OK' if overhead_ok else '>5% REGRESSION'})")
        try:
            base = json.loads(BENCH_JSON.read_text()).get(key)
        except (ValueError, OSError):
            base = None
        base_min = (base or {}).get("steady_min_ms")
        if base_min:
            print(f"               cross-run: {steady_min * 1e3:.2f} ms "
                  f"this run vs {base_min:.2f} ms untraced '{key}' "
                  f"baseline (informational — separate-process runs "
                  f"carry ambient-load noise)")
        key += "_traced"   # never clobber the tracing-disabled baseline

    # ---- perf-trajectory artifact (baseline for future PRs) ---------------
    record_trajectory(key, {
        "requests": args.requests,
        "shape": [args.m, args.k, args.n],
        "cold_ms": round(cold * 1e3, 3),
        "steady_ms": round(steady * 1e3, 4),
        "steady_min_ms": round(steady_min * 1e3, 4),
        "speedup": round(speedup, 2),
        "hit_rate": round(hit_rate, 4),
        "retraces_after_warmup": retraces,
        "drain_ms_per_request": round(drain_s / len(uids) * 1e3, 4),
        "peak_workspace_bytes": engine.arena.peak_bytes,
        "arena_hit_rate": round(engine.arena.hit_rate, 4),
        "table_accesses": access,
        "phases_ms": phases_ms,
        "trace_tax": trace_tax,
        "traced": bool(args.trace),
        "git_rev": git_rev(BENCH_JSON.parent),
        "recorded_at": utc_now_iso(),
    })
    print(f"trajectory:    {BENCH_JSON.name} <- {key}")

    ok = (speedup >= 5.0 and hit_rate >= 0.90 and retraces == 0
          and parity and access_ok and headroom_ok and policy_ok
          and trace_ok and overhead_ok)
    print()
    print("PASS" if ok else "FAIL",
          f"(speedup {speedup:.1f}x, hit rate {hit_rate * 100:.1f}%, "
          f"{retraces} steady-state retraces"
          + ("" if parity else ", parity MISMATCH")
          + ("" if access_ok else ", access reduction < 1.5x")
          + ("" if headroom_ok else ", adaptive steady > 2x fixed-2x")
          + ("" if policy_ok else ", requests bypassed the AUTO policy")
          + ("" if trace_ok else ", trace missing required spans")
          + ("" if overhead_ok else ", tracing overhead > 5%")
          + ")")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
