"""Plan-cache effectiveness on a streaming request pipeline.

Acceptance targets (ISSUE 1, extended by ISSUE 2 to the hash method): on
a stream of >=20 same-bucket SpGEMM requests, steady-state per-call
wall-clock must be >=5x lower than the first (cold-trace) call, with a
reported plan-cache hit rate >=90% and ZERO retraces after warmup.

The stream models serving traffic: distinct matrices whose storage lands
in one pow-2 capacity bucket, so every request after the first reuses the
cached specialized plan and its jitted executable.  ``--method hash``
exercises the bin-count-bucketed hash steady state: the warmup prefix may
grow the learned launch schedule (rung discovery), after which the gate
requires the jitted path to serve every request without recompiling.  A
second phase pushes the same stream through ``submit``/``drain`` to
exercise the batched, completion-order-finalized path.

``--shards N`` (ISSUE 3) runs the whole stream through the partition-
aware engine: every request fans out into N flop-balanced row-block
shards whose plans must come from the cache (hit rate >=90% across shard
plans, zero retraces after warmup), and the merged result must be
bitwise-identical in nnz/structure to the unsharded path.

Run:  PYTHONPATH=src python benchmarks/bench_engine.py [--smoke]
          [--method hash] [--shards 2]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.core import SpgemmConfig, next_bucket, random_csr, spgemm_reference
from repro.engine import SpgemmEngine, total_traces


def build_stream(n_requests: int, m: int, k: int, n: int, avg: float):
    """Distinct matrices canonicalized to ONE shape-bucket signature."""
    pairs = []
    for s in range(n_requests):
        A = random_csr(jax.random.PRNGKey(2 * s), m, k, avg_nnz_per_row=avg)
        B = random_csr(jax.random.PRNGKey(2 * s + 1), k, n,
                       avg_nnz_per_row=avg)
        pairs.append((A, B))
    # Same-bucket premise: pad every operand to the stream-wide pow-2
    # bucket (the serving tier's batching discipline).
    cap_a = next_bucket(max(A.capacity for A, _ in pairs))
    cap_b = next_bucket(max(B.capacity for _, B in pairs))
    return [(A.with_capacity(cap_a), B.with_capacity(cap_b))
            for A, B in pairs]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (~30 s)")
    ap.add_argument("--method", choices=("esc", "hash"), default="esc",
                    help="accumulator method for the whole stream")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--warmup", type=int, default=4,
                    help="requests before the zero-retrace gate arms "
                         "(cold call + schedule/rung discovery)")
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--avg", type=float, default=4.0)
    ap.add_argument("--shards", type=int, default=1,
                    help="row-block shards per request (partition-aware "
                         "engine; 1 = unsharded)")
    ap.add_argument("--check", action="store_true",
                    help="verify every result against the dense oracle")
    args = ap.parse_args(argv)
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if args.smoke:
        args.requests, args.m, args.k, args.n = 20, 64, 64, 64
    if not 0 < args.warmup < args.requests:
        ap.error("--warmup must be in [1, effective --requests)")

    stream = build_stream(args.requests, args.m, args.k, args.n, args.avg)
    engine = SpgemmEngine(SpgemmConfig(method=args.method),
                          shards=args.shards)

    # ---- phase 1: per-call wall-clock over the stream ---------------------
    times = []
    warm_traces = 0
    for i, (A, B) in enumerate(stream):
        t0 = time.perf_counter()
        res = engine.execute(A, B)
        jax.block_until_ready(res.C.val)
        times.append(time.perf_counter() - t0)
        if i == args.warmup - 1:
            # A schedule grow on this very request leaves the rebuild (and
            # its one retrace) pending; absorb it with an untimed repeat of
            # an already-admitted pair before the gate arms.
            jax.block_until_ready(engine.execute(A, B).C.val)
            warm_traces = total_traces()   # retrace gate arms here
        if args.check:
            ref = np.asarray(spgemm_reference(A, B))
            np.testing.assert_allclose(np.asarray(res.C.to_dense()), ref,
                                       rtol=1e-4, atol=1e-4)

    cold = times[0]
    tail = times[len(times) // 2:]
    steady = sum(tail) / len(tail)
    speedup = cold / steady
    hit_rate = engine.cache.hit_rate
    retraces = total_traces() - warm_traces

    print("request,call_ms")
    for i, t in enumerate(times):
        print(f"{i},{t * 1e3:.2f}")
    print()
    print(f"method:        {args.method:>9s}")
    print(f"cold call:     {cold * 1e3:9.1f} ms  (trace + compile)")
    print(f"steady state:  {steady * 1e3:9.2f} ms  "
          f"(mean of last {len(tail)} calls)")
    print(f"speedup:       {speedup:9.1f} x   (target >= 5x)")
    print(f"hit rate:      {hit_rate * 100:9.1f} %   (target >= 90%)")
    print(f"hot traces:    {total_traces():9d}  "
          f"({retraces} after {args.warmup}-request warmup, target 0)")

    # ---- sharded parity: merged C must match the unsharded path ----------
    parity = True
    if args.shards > 1:
        A0, B0 = stream[0]
        base = SpgemmEngine(SpgemmConfig(method=args.method)).execute(A0, B0)
        res0 = engine.execute(A0, B0)
        nnz = base.total_nnz
        parity = (
            res0.total_nnz == nnz
            and np.array_equal(np.asarray(res0.C.rpt), np.asarray(base.C.rpt))
            and np.array_equal(np.asarray(res0.C.col)[:nnz],
                               np.asarray(base.C.col)[:nnz])
            and np.allclose(np.asarray(res0.C.val)[:nnz],
                            np.asarray(base.C.val)[:nnz]))
        print(f"shard parity:  {'OK' if parity else 'MISMATCH':>9s}  "
              f"({args.shards} shards vs unsharded: nnz/rpt/col/val)")

    # ---- phase 2: batched submit/drain (double-buffered overlap) ----------
    uids = [engine.submit(A, B) for A, B in stream]
    t0 = time.perf_counter()
    results = engine.drain()
    jax.block_until_ready([results[u].C.val for u in uids])
    drain_s = time.perf_counter() - t0
    print(f"drain:         {drain_s * 1e3:9.1f} ms for {len(uids)} requests "
          f"({drain_s / len(uids) * 1e3:.2f} ms/req, "
          f"{engine.stats.overlapped} overlapped, "
          f"{engine.stats.reordered} reordered)")
    print()
    print(engine.report())

    ok = (speedup >= 5.0 and hit_rate >= 0.90 and retraces == 0
          and parity)
    print()
    print("PASS" if ok else "FAIL",
          f"(speedup {speedup:.1f}x, hit rate {hit_rate * 100:.1f}%, "
          f"{retraces} steady-state retraces"
          + ("" if parity else ", shard parity MISMATCH") + ")")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
