"""Benchmark timing discipline (paper §6): 1 warmup + N timed reps, mean."""
from __future__ import annotations

import time
from typing import Callable

import jax

REPS = 3          # the paper uses 10; CPU wall-times here are seconds-scale


def timeit(fn: Callable, *args, reps: int = REPS, **kw) -> float:
    """Mean seconds per call: one warmup, then ``reps`` timed runs."""
    jax.block_until_ready(fn(*args, **kw))       # warmup (compile)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def gflops(nprod: int, seconds: float) -> float:
    """Paper's metric: 2*n_prod / time."""
    return 2.0 * nprod / seconds / 1e9


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
