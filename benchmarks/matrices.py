"""Synthetic SuiteSparse-analog suite (paper Table 3).

The container is offline, so the 26 benchmark matrices are SYNTHESIZED to
match Table 3's row counts, mean/max nnz-per-row and structural family
(banded FEM-like, power-law web/circuit-like, uniform).  Sizes default to
1/SCALE of the originals so CPU wall-times stay in seconds; ``--full``
generates the original row counts.  Every generated matrix's achieved
stats are reported next to the paper's, so the fidelity of the analog is
visible in the output.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import numpy as np

from repro.core import CSR, random_csr


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    name: str
    rows: int
    avg_nnz: float          # paper's Nnz/row
    max_nnz: int            # paper's Max nnz/row
    dist: str               # banded | powerlaw | uniform
    large: bool = False     # paper's "large" group (cuSPARSE OOM group)
    paper_cr: float = 0.0   # paper's compression ratio of A^2


# Paper Table 3, 19 "normal" + 7 "large" matrices.
TABLE3: List[MatrixSpec] = [
    MatrixSpec("m133-b3", 200200, 4.0, 4, "uniform", paper_cr=1.01),
    MatrixSpec("mac_econ_fwd500", 206500, 6.2, 44, "uniform", paper_cr=1.13),
    MatrixSpec("patents_main", 240547, 2.3, 206, "powerlaw", paper_cr=1.14),
    MatrixSpec("webbase-1M", 1000005, 3.1, 4700, "powerlaw", paper_cr=1.36),
    MatrixSpec("mc2depi", 525825, 4.0, 4, "uniform", paper_cr=1.60),
    MatrixSpec("scircuit", 170998, 5.6, 353, "powerlaw", paper_cr=1.66),
    MatrixSpec("mario002", 389874, 5.4, 7, "uniform", paper_cr=1.99),
    MatrixSpec("cage12", 130228, 15.6, 33, "banded", paper_cr=2.27),
    MatrixSpec("majorbasis", 160000, 10.9, 11, "banded", paper_cr=2.33),
    MatrixSpec("offshore", 259789, 16.3, 31, "banded", paper_cr=3.05),
    MatrixSpec("2cubes_sphere", 101492, 16.2, 31, "banded", paper_cr=3.06),
    MatrixSpec("poisson3Da", 13514, 26.1, 110, "banded", paper_cr=3.98),
    MatrixSpec("filter3D", 106437, 25.4, 112, "banded", paper_cr=4.26),
    MatrixSpec("mono_500Hz", 169410, 29.7, 719, "powerlaw", paper_cr=4.93),
    MatrixSpec("conf5_4-8x8-05", 49152, 39.0, 39, "banded", paper_cr=6.85),
    MatrixSpec("cant", 62451, 64.2, 78, "banded", paper_cr=15.45),
    MatrixSpec("consph", 83334, 72.1, 81, "banded", paper_cr=17.48),
    MatrixSpec("shipsec1", 140874, 55.5, 102, "banded", paper_cr=18.71),
    MatrixSpec("rma10", 46835, 50.7, 145, "banded", paper_cr=19.81),
    MatrixSpec("delaunay_n24", 16777216, 6.0, 26, "banded", True, 1.83),
    MatrixSpec("cage15", 5154859, 19.2, 47, "banded", True, 2.24),
    MatrixSpec("wb-edu", 9845725, 5.8, 3841, "powerlaw", True, 2.48),
    MatrixSpec("cop20k_A", 121192, 21.7, 81, "banded", True, 4.27),
    MatrixSpec("hood", 220542, 48.8, 77, "banded", True, 16.41),
    MatrixSpec("pwtk", 217918, 53.4, 180, "banded", True, 19.10),
    MatrixSpec("pdb1HYS", 36417, 119.3, 204, "banded", True, 28.34),
]

NORMAL = [m for m in TABLE3 if not m.large]
LARGE = [m for m in TABLE3 if m.large]

DEFAULT_SCALE = 32
LARGE_SCALE = 512


def generate(spec: MatrixSpec, *, scale: int | None = None,
             seed: int = 0) -> CSR:
    """Square synthetic analog of one Table-3 matrix (A for the A^2 bench)."""
    s = scale if scale is not None else (
        LARGE_SCALE if spec.large else DEFAULT_SCALE)
    n = max(spec.rows // s, 256)
    return random_csr(
        jax.random.PRNGKey(hash(spec.name) % (2 ** 31) + seed), n, n,
        avg_nnz_per_row=spec.avg_nnz,
        max_nnz_per_row=min(spec.max_nnz, n),
        distribution=spec.dist)


def stats(A: CSR) -> Dict[str, float]:
    per_row = np.asarray(A.nnz_per_row())
    return {
        "rows": A.nrows,
        "nnz": int(A.nnz()),
        "avg_nnz": float(per_row.mean()),
        "max_nnz": int(per_row.max()),
    }
