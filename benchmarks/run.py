"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each module's docstring
for the paper artifact it reproduces).
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single bench module (e.g. 'overall')")
    args = ap.parse_args()

    from . import (bench_binning, bench_binning_ranges, bench_hashing,
                   bench_moe_dispatch, bench_overall, bench_overlap)

    benches = {
        "overall": bench_overall.run,            # Fig 5/6
        "binning": bench_binning.run,            # Fig 7/8
        "hashing": bench_hashing.run,            # Fig 9
        "binning_ranges": bench_binning_ranges.run,  # Fig 10/11
        "overlap": bench_overlap.run,            # §6.3.4/6.3.5
        "moe_dispatch": bench_moe_dispatch.run,  # beyond-paper
    }
    if args.only:
        benches = {args.only: benches[args.only]}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        try:
            fn()
        except Exception as e:                   # pragma: no cover
            failures += 1
            print(f"{name},FAILED,{type(e).__name__}: {e}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
