"""Fig. 7/8 reproduction: binning cost — fused two-pass vs naive multi-pass.

The paper's claim: nsparse/spECK spend ~10% of total SpGEMM time binning
(global-memory atomics, one pass per bin); OpSparse's shared-memory binning
is ~1.5%.  Our analogs:
  * fused    — core.binning.bin_rows (histogram + cumsum + one stable sort,
               all device-side, one dispatch) = the shared-memory method.
  * naive    — one PASS PER BIN with a host sync each (boolean mask ->
               nonzero -> separate allocation), the global-memory
               many-kernel pattern of the baselines.

Reported: absolute binning time and binning as % of total spgemm() time.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SpgemmConfig, bin_rows_for_ladder, nprod_into_rpt,
                        spgemm, symbolic_ladder)

from .common import timeit
from .matrices import NORMAL, generate


def naive_binning(sizes, ladder):
    """One masked pass per bin + host syncs (baseline pattern)."""
    out = []
    prev = -1
    bounds = list(ladder.upper) + [np.inf]
    sizes_np = np.asarray(sizes)          # host roundtrip (global memory)
    for ub in bounds:
        members = np.nonzero((sizes_np > prev) & (sizes_np <= ub))[0]
        out.append(jnp.asarray(members))  # separate allocation per bin
        prev = ub
    return out


def run() -> List[str]:
    rows = []
    lad = symbolic_ladder(1.2)
    for spec in NORMAL[:12]:
        A = generate(spec)
        nprod = nprod_into_rpt(A, A)[:A.nrows]

        t_fused = timeit(lambda: bin_rows_for_ladder(nprod, lad).bins)
        t_naive = timeit(lambda: naive_binning(nprod, lad)[0])

        res = spgemm(A, A, SpgemmConfig(timing=True))
        total = sum(res.timings.values())
        bin_t = (res.timings.get("symbolic_binning", 0)
                 + res.timings.get("numeric_binning", 0))
        rows.append(
            f"bench_binning/{spec.name},{t_fused*1e6:.0f},"
            f"naive_us={t_naive*1e6:.0f};speedup={t_naive/t_fused:.1f}x;"
            f"binning_pct_of_total={100*bin_t/max(total,1e-9):.1f}%")
        print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
