"""Fig. 9 reproduction: single-access vs multi-access hashing + fusion.

The paper's §5.2 claim: one hash-table transaction per probe iteration
(instead of nsparse/spECK's check-then-CAS) gives ~1.09-1.10x on the
symbolic/numeric steps.  Our Pallas kernels implement BOTH disciplines and
count table transactions exactly (the architecture-independent quantity);
interpret-mode wall time is also reported (CPU-emulated, directional).

Extended (ISSUE 4): the same counters measure the FUSED one-build pipeline
(``fused_binned``, optionally row-packed) against the two-pass total —
building each row's table once instead of twice should roughly halve the
per-row transactions; the reported ``fused_access_reduction`` is the
measured (sym + num) / fused ratio on both probe disciplines.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core import (bin_rows_for_ladder, next_bucket, nprod_into_rpt,
                        random_csr, symbolic_ladder, numeric_ladder, esc)
from repro.core.analysis import exclusive_sum_in_place
from repro.kernels import spgemm_hash

from .common import timeit


CASES = [
    ("uniform-64x", 256, 2048, 6.0, "uniform"),
    ("powerlaw", 192, 1024, 8.0, "powerlaw"),
    ("banded-fem", 256, 2048, 12.0, "banded"),
]


def run() -> List[str]:
    rows = []
    for name, m, n, avg, dist in CASES:
        A = random_csr(jax.random.PRNGKey(1), m, n, avg_nnz_per_row=avg,
                       distribution=dist)
        B = random_csr(jax.random.PRNGKey(2), n, m, avg_nnz_per_row=avg,
                       distribution=dist)
        nprod = nprod_into_rpt(A, B)[:m]
        lad = symbolic_ladder(1.2)
        bn = bin_rows_for_ladder(nprod, lad)

        def sym(single):
            nnz, acc = spgemm_hash.symbolic_binned(
                A, B, bn, lad, prod_capacity=1, single_access=single,
                collect_accesses=True)
            return nnz, int(acc)

        (_, acc_s) = sym(True)
        (_, acc_m) = sym(False)
        t_s = timeit(lambda: sym(True)[0], reps=2)
        t_m = timeit(lambda: sym(False)[0], reps=2)

        # numeric step
        nnz_buf = esc.symbolic(A, B, prod_capacity=next_bucket(
            int(nprod.sum())))
        rpt = exclusive_sum_in_place(nnz_buf)
        nlad = numeric_ladder(2.0)
        nbn = bin_rows_for_ladder(nnz_buf[:m], nlad)
        cap = next_bucket(int(rpt[-1]))

        def num(single):
            C, acc = spgemm_hash.numeric_binned(
                A, B, rpt, nbn, nlad, prod_capacity=1, nnz_capacity=cap,
                single_access=single, collect_accesses=True)
            return C.val, int(acc)

        (_, nacc_s) = num(True)
        (_, nacc_m) = num(False)

        # fused one-build pipeline (opt. 2 extended): one table build per
        # row replaces the symbolic+numeric double build; packed kernels
        # batch small rows per VMEM tile (identical transaction counts —
        # packing changes occupancy, not probing).
        def fused(single, packed):
            C, acc = spgemm_hash.fused_binned(
                A, B, bn, lad, nnz_capacity=cap, single_access=single,
                row_packing=packed, collect_accesses=True)
            return C.val, int(acc)

        (_, facc_s) = fused(True, True)
        (_, facc_m) = fused(False, True)

        rows.append(
            f"bench_hashing/{name},{t_s*1e6:.0f},"
            f"sym_accesses_single={acc_s};sym_accesses_multi={acc_m};"
            f"sym_access_reduction={acc_m/max(acc_s,1):.3f}x;"
            f"num_accesses_single={nacc_s};num_accesses_multi={nacc_m};"
            f"num_access_reduction={nacc_m/max(nacc_s,1):.3f}x;"
            f"fused_accesses_single={facc_s};fused_accesses_multi={facc_m};"
            f"fused_access_reduction={(acc_s+nacc_s)/max(facc_s,1):.3f}x;"
            f"fused_access_reduction_multi={(acc_m+nacc_m)/max(facc_m,1):.3f}x;"
            f"sym_time_speedup={t_m/max(t_s,1e-9):.2f}x")
        print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
