"""Fig. 5/6 reproduction: overall SpGEMM FLOPS on the Table-3 suite.

Contestants (CPU-backend analogs of the paper's lineup):
  * opsparse      — our two-phase binned pipeline (ESC accumulator, fused
                    workspace, async dispatch) = the paper's system.
  * opsparse-fused— beyond-paper single-expansion variant (fuse_esc).
  * bcoo          — ``jax.experimental.sparse`` BCOO matmul: the vendor
                    -library stand-in (cuSPARSE analog).

Absolute GFLOPS are CPU numbers; the paper's claims are RELATIVE (OpSparse
beats the vendor library on every matrix) and those relative positions are
what this benchmark validates.  Skips the dense-oracle on big inputs.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core import SpgemmConfig, spgemm, total_nprod

from .common import gflops, timeit
from .matrices import NORMAL, LARGE, generate


def _bcoo_square(A_bcoo):
    from jax.experimental import sparse as jsparse
    return jsparse.bcoo_dot_general(
        A_bcoo, A_bcoo, dimension_numbers=(((1,), (0,)), ((), ())))


def run(full: bool = False, include_large: bool = True) -> List[str]:
    rows = []
    specs = NORMAL + (LARGE if include_large else [])
    for spec in specs:
        A = generate(spec)
        npd = int(total_nprod(A, A))

        def run_opsparse():
            return spgemm(A, A, SpgemmConfig(method="esc")).C.val

        def run_fused():
            return spgemm(A, A, SpgemmConfig(method="esc",
                                             fuse_esc=True)).C.val

        t_ours = timeit(run_opsparse)
        t_fused = timeit(run_fused)

        t_bcoo = None
        if A.nrows <= 2048 and int(A.nnz()) <= 20000:
            # vendor-library stand-in; jax.experimental.sparse's
            # sparse-sparse dot overflows int32 internally on larger
            # inputs (guarded — its failure IS a datapoint: the paper's
            # cuSPARSE baseline also falls over on its "large" group)
            try:
                from jax.experimental import sparse as jsparse
                A_bcoo = jsparse.BCOO.fromdense(A.to_dense())
                t_bcoo = timeit(lambda: _bcoo_square(A_bcoo).data)
            except Exception:
                t_bcoo = None

        res = spgemm(A, A)
        cr = npd / max(res.total_nnz, 1)
        base = (f"bench_overall/{spec.name},{t_ours*1e6:.0f},"
                f"gflops={gflops(npd, t_ours):.3f};"
                f"fused_gflops={gflops(npd, t_fused):.3f};")
        if t_bcoo:
            base += (f"bcoo_gflops={gflops(npd, t_bcoo):.3f};"
                     f"speedup_vs_bcoo={t_bcoo/t_ours:.2f}x;")
        base += f"cr={cr:.2f};paper_cr={spec.paper_cr}"
        rows.append(base)
        print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
