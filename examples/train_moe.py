"""End-to-end driver: train a ~100M-param MoE LM for a few hundred steps.

The MoE token dispatch uses the paper's two-pass binning
(core.binning.bin_by_id) — see DESIGN.md §4.  The loop exercises the full
substrate: synthetic data pipeline, AdamW, gradient accumulation, async
checkpointing, NaN rollback, straggler accounting.

Run:  PYTHONPATH=src python examples/train_moe.py [--steps 300]
"""
import argparse
import logging
import time

import jax

from repro.configs import get_arch
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.launch.steps import init_train_state, make_train_step
from repro.models.model import Model
from repro.models.param import param_count
from repro.optim import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

logging.basicConfig(level=logging.INFO, format="%(message)s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_moe_ckpt")
    args = ap.parse_args()

    # olmoe topology shrunk to ~100M params for a CPU-feasible run
    cfg = get_arch("olmoe-1b-7b").replace(
        name="olmoe-100m", num_layers=6, d_model=384, num_heads=6,
        num_kv_heads=6, d_ff=512, vocab_size=8192, num_experts=16,
        experts_per_token=4, dtype="float32")
    model = Model(cfg)
    n_params = param_count(model.param_specs())
    print(f"arch {cfg.name}: {n_params/1e6:.1f}M params "
          f"({cfg.num_experts} experts, top-{cfg.experts_per_token})")

    state = init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(
        make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=20,
                                           total_steps=args.steps),
                        microbatches=1))
    data = SyntheticTokenStream(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8))

    tr = Trainer(step_fn, data,
                 TrainerConfig(total_steps=args.steps, ckpt_every=100,
                               ckpt_dir=args.ckpt, log_every=20))
    tr.install_signal_handlers()
    t0 = time.perf_counter()
    state, step = tr.fit(state, resume=False)
    dt = time.perf_counter() - t0

    first = tr.metrics_history[0]["loss"]
    last = tr.metrics_history[-1]["loss"]
    print(f"\ntrained {step} steps in {dt:.1f}s "
          f"({dt/max(step,1)*1e3:.0f} ms/step)")
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first * 0.8 else 'check data/config'})")


if __name__ == "__main__":
    main()
