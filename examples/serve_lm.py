"""Batched serving with continuous batching (per-slot positions).

Also demonstrates the robustness surface: an oversized prompt comes back
as a structured rejection (``req.error``) instead of killing the engine,
and the run's telemetry is scraped from a live ``/metrics`` endpoint
(the same stdlib HTTP server the SpGEMM service uses).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time
import urllib.request

import jax
import numpy as np

from repro.configs import get_arch
from repro.engine.telemetry import merge_sample_blocks
from repro.models.model import Model
from repro.serve.engine import Request, ServingEngine

cfg = get_arch("qwen3-1.7b").reduced().replace(
    num_layers=4, d_model=128, d_ff=256, vocab_size=1024, dtype="float32")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

engine = ServingEngine(model, params, max_batch=4, max_len=96,
                       telemetry=True)
rng = np.random.default_rng(0)
n_req = 10
requests = {}
for uid in range(n_req):
    plen = int(rng.integers(4, 24))
    requests[uid] = Request(
        uid=uid, prompt=rng.integers(0, 1024, plen).astype(np.int32),
        max_new_tokens=12)
    engine.submit(requests[uid])
# One malformed request: its prompt cannot fit the cache.  The engine
# must reject it structurally and keep serving everyone else.
requests[n_req] = Request(
    uid=n_req, prompt=rng.integers(0, 1024, 200).astype(np.int32))
engine.submit(requests[n_req])

t0 = time.perf_counter()
results = engine.run()
dt = time.perf_counter() - t0
served = [uid for uid in results if requests[uid].error is None]
rejected = [uid for uid in results if requests[uid].error is not None]
tokens = sum(len(v) for v in results.values())
print(f"served {len(served)}/{n_req + 1} requests "
      f"({len(rejected)} rejected), {tokens} tokens "
      f"in {dt:.1f}s ({tokens/dt:.1f} tok/s on CPU)")
for uid in sorted(served)[:3]:
    print(f"  req {uid}: {results[uid]}")
for uid in rejected:
    print(f"  req {uid}: REJECTED — {requests[uid].error}")

# -- scrape the run's metrics over HTTP ------------------------------------
# ServingEngine publishes into the same registry machinery as the SpGEMM
# engines; serve its sample blocks the way SpgemmService does.
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
import threading


class _Handler(BaseHTTPRequestHandler):
    def do_GET(self):
        body = merge_sample_blocks(
            [engine.telemetry.registry.sample_blocks()]).encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):
        pass


server = ThreadingHTTPServer(("127.0.0.1", 0), _Handler)
threading.Thread(target=server.serve_forever, daemon=True).start()
url = f"http://127.0.0.1:{server.server_address[1]}/metrics"
body = urllib.request.urlopen(url).read().decode()
server.shutdown()
server.server_close()

print(f"\n/metrics scrape ({url}):")
for line in body.splitlines():
    if line.startswith("opsparse_serve_") or "# TYPE opsparse_serve" in line:
        print(f"  {line}")
