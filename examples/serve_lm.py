"""Batched serving with continuous batching (per-slot positions).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.model import Model
from repro.serve.engine import Request, ServingEngine

cfg = get_arch("qwen3-1.7b").reduced().replace(
    num_layers=4, d_model=128, d_ff=256, vocab_size=1024, dtype="float32")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

engine = ServingEngine(model, params, max_batch=4, max_len=96)
rng = np.random.default_rng(0)
n_req = 10
for uid in range(n_req):
    plen = int(rng.integers(4, 24))
    engine.submit(Request(uid=uid,
                          prompt=rng.integers(0, 1024, plen).astype(np.int32),
                          max_new_tokens=12))

t0 = time.perf_counter()
results = engine.run()
dt = time.perf_counter() - t0
tokens = sum(len(v) for v in results.values())
print(f"served {len(results)}/{n_req} requests, {tokens} tokens "
      f"in {dt:.1f}s ({tokens/dt:.1f} tok/s on CPU)")
for uid in sorted(results)[:3]:
    print(f"  req {uid}: {results[uid]}")
