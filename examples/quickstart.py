"""Quickstart: the OpSparse SpGEMM public API.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import CSR, SpgemmConfig, random_csr, spgemm

# A sparse matrix with a heavy-tailed row distribution (webbase-like).
A = random_csr(jax.random.PRNGKey(0), 2000, 2000, avg_nnz_per_row=8.0,
               max_nnz_per_row=200, distribution="powerlaw")

# C = A @ A, the paper's benchmark computation — two-phase, binned.
result = spgemm(A, A, SpgemmConfig(method="esc", timing=True))
C = result.C

print(f"A: {A.shape}, nnz={int(A.nnz())}")
print(f"C = A@A: nnz={result.total_nnz}, intermediate products="
      f"{result.total_nprod}, compression ratio={result.compression_ratio:.2f}")
print("per-step timings (ms):",
      {k: round(v * 1e3, 2) for k, v in result.timings.items()})
print("symbolic bin sizes:", np.asarray(result.sym_binning.bin_size))
print("numeric  bin sizes:", np.asarray(result.num_binning.bin_size))

# Verify against the dense oracle on a small slice.
small = random_csr(jax.random.PRNGKey(1), 64, 64, avg_nnz_per_row=4.0)
res = spgemm(small, small)
ref = np.asarray(small.to_dense()) @ np.asarray(small.to_dense())
np.testing.assert_allclose(np.asarray(res.C.to_dense()), ref, rtol=1e-5,
                           atol=1e-5)
print("dense-oracle check: OK")
