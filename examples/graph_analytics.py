"""SpGEMM application: multi-source BFS frontier expansion via A @ F.

The paper motivates SpGEMM with graph workloads (multi-source BFS, Markov
clustering).  Frontier expansion for many sources at once IS a sparse-
sparse product: adjacency (N x N) @ frontier (N x S).

Run:  PYTHONPATH=src python examples/graph_analytics.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CSR, SpgemmConfig, spgemm, random_csr

N, SOURCES, HOPS = 3000, 32, 4
adj = random_csr(jax.random.PRNGKey(0), N, N, avg_nnz_per_row=6.0,
                 distribution="powerlaw")

# one-hot frontier per source column
rng = np.random.default_rng(0)
srcs = rng.choice(N, SOURCES, replace=False)
dense_f = np.zeros((N, SOURCES), np.float32)
dense_f[srcs, np.arange(SOURCES)] = 1.0
frontier = CSR.from_dense(dense_f)

visited = dense_f > 0
for hop in range(HOPS):
    res = spgemm(adj, frontier, SpgemmConfig(method="esc"))
    reached = np.asarray(res.C.to_dense()) > 0
    new = reached & ~visited
    visited |= reached
    frontier = CSR.from_dense(new.astype(np.float32))
    print(f"hop {hop + 1}: frontier nnz={int(frontier.nnz())}, "
          f"visited={int(visited.sum())}/{N * SOURCES} pairs, "
          f"CR={res.compression_ratio:.2f}")

print("multi-source BFS done —", int(visited.any(axis=1).sum()),
      "nodes reached from", SOURCES, "sources")
