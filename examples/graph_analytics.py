"""SpGEMM applications on one engine: multi-source BFS and A·A powers.

The paper motivates SpGEMM with graph workloads (multi-source BFS, Markov
clustering).  Frontier expansion for many sources at once IS a sparse-
sparse product: adjacency (N x N) @ frontier (N x S); Markov-clustering's
expansion step is the chained square A·A.  Both are *streams* of products
over one adjacency matrix — exactly what the execution-plan engine
amortizes: the adjacency signature repeats every hop, so after the first
hop the plans (and their jitted executables) come from the cache.

Run:  PYTHONPATH=src python examples/graph_analytics.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CSR, SpgemmConfig, random_csr
from repro.engine import SpgemmEngine

N, SOURCES, HOPS = 3000, 32, 4
adj = random_csr(jax.random.PRNGKey(0), N, N, avg_nnz_per_row=6.0,
                 distribution="powerlaw")

engine = SpgemmEngine(SpgemmConfig(method="esc"))

# ---- multi-source BFS: adjacency @ frontier, chained over hops -----------
# Frontiers grow hop over hop; padding them to ONE storage bucket keeps
# every hop on the same plan signature (the serving tier's batching
# discipline), so the engine reuses one cached executable across hops.
FRONTIER_BUCKET = 8192
PLAN_BUCKETS = 32768      # final-hop-sized product/nnz capacity bound


def pad_frontier(f: CSR) -> CSR:
    # with_capacity truncates silently past the bucket — fail loudly
    # instead (a bigger BFS needs a bigger bucket, not a wrong answer).
    assert int(f.nnz()) <= FRONTIER_BUCKET, (int(f.nnz()), FRONTIER_BUCKET)
    return f.with_capacity(FRONTIER_BUCKET)


rng = np.random.default_rng(0)
srcs = rng.choice(N, SOURCES, replace=False)
dense_f = np.zeros((N, SOURCES), np.float32)
dense_f[srcs, np.arange(SOURCES)] = 1.0
frontier = pad_frontier(CSR.from_dense(dense_f))

# Ahead-of-time specialization: BFS product sizes grow toward the last
# hop, so seed the plan with end-of-BFS-sized buckets up front — every
# hop (including the first) then runs the jitted hot path, no regrows.
engine.prewarm(adj, frontier, prod_bucket=PLAN_BUCKETS,
               nnz_bucket=PLAN_BUCKETS)

visited = dense_f > 0
for hop in range(HOPS):
    res = engine.execute(adj, frontier)
    reached = np.asarray(res.C.to_dense()) > 0
    new = reached & ~visited
    visited |= reached
    frontier = pad_frontier(CSR.from_dense(new.astype(np.float32)))
    print(f"hop {hop + 1}: frontier nnz={int(frontier.nnz())}, "
          f"visited={int(visited.sum())}/{N * SOURCES} pairs, "
          f"CR={res.compression_ratio:.2f}")

print("multi-source BFS done —", int(visited.any(axis=1).sum()),
      "nodes reached from", SOURCES, "sources")

# ---- chained A·A iteration (Markov-clustering expansion step) ------------
# Each squaring reuses the SAME adjacency signature on the left, and the
# batched submit/drain path pipelines the stream through the plan cache
# (drain finalizes in completion order — mixed-size hops don't
# head-of-line block).
P = adj
for it in range(2):
    uid = engine.submit(adj, P)
    P = engine.drain()[uid].C
    print(f"A^{it + 2}: nnz={int(P.nnz())}")

print()
print(engine.report())

# ---- partition-aware engine: row-block sharded BFS hop -------------------
# shards=2 splits the adjacency into two flop-balanced row blocks; each
# shard runs an ordinary (cached) SpGEMM and the merged frontier product
# has identical structure.  Powerlaw adjacencies are exactly where the
# flop split beats an even row split: the heavy-head rows stay together
# in one slim shard.  On a multi-device mesh, pass ``mesh=`` to place
# shard s on the s-th data-axis device (replicated frontier).
sharded = SpgemmEngine(SpgemmConfig(method="esc"), shards=2)
cold = sharded.execute(adj, frontier)
hot = sharded.execute(adj, frontier)       # per-shard plans from the cache
assert hot.total_nnz == cold.total_nnz
spec = next(e.plan.shard_spec for _, e in sharded.cache.items()
            if e.plan.shard_spec is not None)
print(f"\nsharded hop: nnz={hot.total_nnz}, row blocks "
      f"{'/'.join(str(b) for b in spec.bounds)} "
      f"({len(sharded.cache)} plans cached)")
